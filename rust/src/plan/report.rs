//! Ranked plan reports: human-readable tables (the `stp plan` CLI and the
//! `auto_plan` example) and JSON (the `config::json` value type, same
//! idiom as the Chrome traces and run reports).

use std::collections::BTreeMap;
use std::collections::HashSet;

use crate::config::json::Json;
use crate::metrics::{pct, Table};
use crate::schedule::ScheduleKind;

use super::constraints::Reject;
use super::evaluate::Evaluation;

/// Outcome of one [`super::plan`] query: the pruning funnel plus every
/// simulated candidate, ranked feasible-first by simulated throughput.
#[derive(Debug, Clone)]
pub struct PlanReport {
    pub model_name: String,
    /// Pool name: a profile name for uniform pools ("a800-sxm4-80g"), a
    /// spec name for mixed ones ("mixed-a800-h20").
    pub cluster_name: String,
    pub gpus: usize,
    pub mem_cap_bytes: usize,
    pub seq: usize,
    pub mb_size: usize,
    /// Exploration strategy that produced the ranking ("exhaustive",
    /// "beam-8").
    pub search_mode: String,
    /// Raw candidate-space size before any pruning.
    pub n_enumerated: usize,
    /// Dropped by shape rules (TP divisibility, pipeline depth, n_mb).
    pub n_rejected_shape: usize,
    /// Shape rejections broken down by [`Reject`] reason, in
    /// [`Reject::SHAPE_KINDS`] order; the counts sum to
    /// `n_rejected_shape` (CLI `--verbose` prints them).
    pub shape_reject_tallies: Vec<(Reject, usize)>,
    /// Dropped by the closed-form memory pre-filter.
    pub n_pruned_memory: usize,
    /// Dropped by the theory-estimate bound.
    pub n_pruned_theory: usize,
    /// Simulated candidates, ranked (feasible first, throughput desc).
    pub ranked: Vec<Evaluation>,
    /// Executable handoff for the winner (`None` when nothing fit):
    /// serialized by `stp plan --emit-plan`, consumed by
    /// `stp train --plan`.
    pub best_artifact: Option<super::artifact::PlanArtifact>,
}

impl PlanReport {
    /// Number of candidates that went through full simulation.
    pub fn n_simulated(&self) -> usize {
        self.ranked.len()
    }

    /// The chosen plan: the top-ranked *memory-feasible* candidate.
    pub fn best(&self) -> Option<&Evaluation> {
        self.ranked.first().filter(|e| e.feasible)
    }

    /// Feasible candidates in rank order.
    pub fn feasible(&self) -> impl Iterator<Item = &Evaluation> {
        self.ranked.iter().filter(|e| e.feasible)
    }

    /// Distinct schedule kinds among the simulated candidates.
    pub fn kinds_covered(&self) -> usize {
        self.ranked.iter().map(|e| e.candidate.kind).collect::<HashSet<ScheduleKind>>().len()
    }

    /// Render the pruning funnel and the top `top` rows.
    pub fn render(&self, top: usize) -> String {
        let mut t = Table::new(vec![
            "rank", "plan", "samples/s", "MFU %", "TP bub/dev", "PP bub/dev", "peak GB", "fit",
        ]);
        for (i, e) in self.ranked.iter().take(top).enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                e.candidate.label(),
                format!("{:.2}", e.throughput),
                pct(e.mfu),
                format!("{:.3}s", e.tp_bubble_per_dev),
                format!("{:.3}s", e.pp_bubble_per_dev),
                format!("{:.1}", e.peak_mem_bytes as f64 / 1e9),
                if e.sim_failed {
                    "fail".to_string()
                } else if e.feasible {
                    "ok".to_string()
                } else {
                    "OOM".to_string()
                },
            ]);
        }
        let best_line = match self.best() {
            Some(b) => format!(
                "best plan: {}  ({:.2} samples/s, MFU {:.1}%, peak {:.1} GB)",
                b.candidate.label(),
                b.throughput,
                100.0 * b.mfu,
                b.peak_mem_bytes as f64 / 1e9
            ),
            None => "no memory-feasible plan for this budget".to_string(),
        };
        format!(
            "== auto-plan: {} on {} x{} (seq {}, mbsize {}, cap {:.0} GiB, search {})\n\
             candidates: {} enumerated | {} shape-rejected | {} memory-pruned | \
             {} theory-pruned | {} simulated ({} schedule kinds)\n{}\n{}",
            self.model_name,
            self.cluster_name,
            self.gpus,
            self.seq,
            self.mb_size,
            self.mem_cap_bytes as f64 / (1u64 << 30) as f64,
            self.search_mode,
            self.n_enumerated,
            self.n_rejected_shape,
            self.n_pruned_memory,
            self.n_pruned_theory,
            self.n_simulated(),
            self.kinds_covered(),
            t.render(),
            best_line
        )
    }

    /// One line of per-reason shape-reject counts (zero-count reasons
    /// skipped), e.g. `shape rejects: tp-shape 40 | cluster-shape 12`.
    pub fn reject_tally_line(&self) -> String {
        let parts: Vec<String> = self
            .shape_reject_tallies
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(r, n)| format!("{} {}", r.name(), n))
            .collect();
        if parts.is_empty() {
            "shape rejects: none".to_string()
        } else {
            format!("shape rejects: {}", parts.join(" | "))
        }
    }

    /// When no candidate was chosen, a one-line diagnosis of where the
    /// funnel consumed the space (the `stp plan` nonzero-exit message).
    pub fn no_plan_diagnostic(&self) -> String {
        let simulated_oom = self.ranked.iter().filter(|e| !e.feasible).count();
        format!(
            "no feasible plan: {} enumerated, {} shape-rejected, {} memory-pruned, \
             {} theory-pruned, {} simulated but over the {:.0} GiB cap",
            self.n_enumerated,
            self.n_rejected_shape,
            self.n_pruned_memory,
            self.n_pruned_theory,
            simulated_oom,
            self.mem_cap_bytes as f64 / (1u64 << 30) as f64,
        )
    }

    /// Serialize the whole report (query echo + funnel + ranked list).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("model".into(), Json::Str(self.model_name.clone()));
        root.insert("cluster".into(), Json::Str(self.cluster_name.clone()));
        root.insert("gpus".into(), Json::Num(self.gpus as f64));
        root.insert(
            "mem_cap_gib".into(),
            Json::Num(self.mem_cap_bytes as f64 / (1u64 << 30) as f64),
        );
        root.insert("seq".into(), Json::Num(self.seq as f64));
        root.insert("mb_size".into(), Json::Num(self.mb_size as f64));
        root.insert("search_mode".into(), Json::Str(self.search_mode.clone()));
        root.insert("enumerated".into(), Json::Num(self.n_enumerated as f64));
        root.insert("rejected_shape".into(), Json::Num(self.n_rejected_shape as f64));
        let mut tallies = BTreeMap::new();
        for (r, n) in &self.shape_reject_tallies {
            tallies.insert(r.name().to_string(), Json::Num(*n as f64));
        }
        root.insert("rejected_shape_by_reason".into(), Json::Obj(tallies));
        root.insert("pruned_memory".into(), Json::Num(self.n_pruned_memory as f64));
        root.insert("pruned_theory".into(), Json::Num(self.n_pruned_theory as f64));
        root.insert("simulated".into(), Json::Num(self.n_simulated() as f64));
        let candidates: Vec<Json> = self
            .ranked
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let c = &e.candidate;
                let mut o = BTreeMap::new();
                o.insert("rank".into(), Json::Num((i + 1) as f64));
                o.insert("tp".into(), Json::Num(c.tp as f64));
                o.insert("pp".into(), Json::Num(c.pp as f64));
                o.insert("dp".into(), Json::Num(c.dp as f64));
                o.insert("schedule".into(), Json::Str(c.kind.name().into()));
                o.insert("n_mb".into(), Json::Num(c.n_mb as f64));
                o.insert("order".into(), Json::Str(c.order.name().into()));
                o.insert("offload_variant".into(), Json::Num(c.offload_variant as f64));
                o.insert("ac".into(), Json::Str(c.ac.name().into()));
                if let Some(map) = &c.map {
                    o.insert("map".into(), Json::Str(map.label()));
                }
                if c.vpp_gene > 0 {
                    o.insert("vpp".into(), Json::Num(c.vpp() as f64));
                }
                o.insert("throughput".into(), Json::Num(e.throughput));
                o.insert("mfu".into(), Json::Num(e.mfu));
                o.insert("iteration_secs".into(), Json::Num(e.iteration_secs));
                o.insert("dp_grad_secs".into(), Json::Num(e.dp_grad_secs));
                o.insert("tp_bubble_per_dev".into(), Json::Num(e.tp_bubble_per_dev));
                o.insert("pp_bubble_per_dev".into(), Json::Num(e.pp_bubble_per_dev));
                o.insert("peak_gb".into(), Json::Num(e.peak_mem_bytes as f64 / 1e9));
                o.insert("feasible".into(), Json::Bool(e.feasible));
                o.insert("sim_failed".into(), Json::Bool(e.sim_failed));
                Json::Obj(o)
            })
            .collect();
        root.insert("candidates".into(), Json::Arr(candidates));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GroupOrder;
    use crate::plan::space::Candidate;
    use crate::schedule::OffloadParams;

    fn eval(id: usize, kind: ScheduleKind, thr: f64, feasible: bool) -> Evaluation {
        Evaluation {
            candidate: Candidate {
                id,
                tp: 8,
                pp: 2,
                dp: 1,
                kind,
                n_mb: 64,
                order: GroupOrder::Declared,
                offload: OffloadParams::default(),
                offload_variant: 0,
                ac: crate::sim::AcMode::None,
                map: None,
                vpp_gene: 0,
            },
            iteration_secs: 1.0,
            dp_grad_secs: 0.0,
            throughput: thr,
            mfu: 0.4,
            tp_bubble_per_dev: 0.1,
            pp_bubble_per_dev: 0.2,
            peak_mem_bytes: 50_000_000_000,
            feasible,
            sim_failed: false,
        }
    }

    fn report() -> PlanReport {
        PlanReport {
            model_name: "qwen2-12.1b".into(),
            cluster_name: "a800-sxm4-80g".into(),
            gpus: 16,
            mem_cap_bytes: 80 << 30,
            seq: 6144,
            mb_size: 1,
            search_mode: "exhaustive".into(),
            n_enumerated: 10,
            n_rejected_shape: 4,
            shape_reject_tallies: vec![
                (Reject::TpShape, 3),
                (Reject::PipelineShape, 0),
                (Reject::MicrobatchShape, 1),
                (Reject::ClusterShape, 0),
            ],
            n_pruned_memory: 2,
            n_pruned_theory: 1,
            ranked: vec![
                eval(3, ScheduleKind::Stp, 30.0, true),
                eval(1, ScheduleKind::OneF1BInterleaved, 25.0, true),
                eval(2, ScheduleKind::GPipe, 40.0, false),
            ],
            best_artifact: None,
        }
    }

    #[test]
    fn best_is_top_feasible() {
        let r = report();
        assert_eq!(r.best().unwrap().candidate.id, 3);
        assert_eq!(r.n_simulated(), 3);
        assert_eq!(r.kinds_covered(), 3);
    }

    #[test]
    fn render_contains_funnel_and_best() {
        let out = report().render(10);
        assert!(out.contains("10 enumerated"));
        assert!(out.contains("best plan: tp8-pp2-dp1 stp m64"));
        assert!(out.contains("OOM"));
    }

    #[test]
    fn reject_tallies_render_and_diagnose() {
        let mut r = report();
        assert_eq!(r.reject_tally_line(), "shape rejects: tp-shape 3 | microbatch-shape 1");
        // Empty ranking: the diagnostic names every funnel stage.
        r.ranked.clear();
        let d = r.no_plan_diagnostic();
        assert!(d.contains("no feasible plan"), "{d}");
        assert!(d.contains("4 shape-rejected"), "{d}");
        assert!(d.contains("2 memory-pruned"), "{d}");
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let r = report();
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("gpus").unwrap().as_usize(), Some(16));
        assert_eq!(j.get("search_mode").unwrap().as_str(), Some("exhaustive"));
        let by_reason = j.get("rejected_shape_by_reason").unwrap();
        assert_eq!(by_reason.get("tp-shape").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("candidates").unwrap().as_arr().unwrap().len(), 3);
        let top = j.get("candidates").unwrap().idx(0).unwrap();
        assert_eq!(top.get("schedule").unwrap().as_str(), Some("stp"));
        assert!(matches!(top.get("feasible"), Some(Json::Bool(true))));
    }
}
