//! Hard admissibility rules and the memory-feasibility pre-filter.
//!
//! Following *Pipeline Parallelism with Controllable Memory*, memory is a
//! first-class search constraint: a candidate whose Table-1 peak
//! (`peak_act_ma · M_a + static`) cannot fit the device is rejected
//! *before* any schedule is built or simulated. Shape rules mirror the
//! generators' own asserts (so the search never panics a builder) plus
//! Megatron's TP divisibility requirements.

use crate::cluster::ClusterSpec;
use crate::schedule::{theory, ScheduleKind};
use crate::sim::CostModel;

use super::space::{Candidate, PlanModel};

/// Why a candidate was rejected before simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reject {
    /// TP does not divide the model's heads / FFN width.
    TpShape,
    /// Too many (or too few) pipeline chunks for the layer count.
    PipelineShape,
    /// Microbatch count violates a generator's constraint
    /// (1F1B-I needs `n_mb % pp == 0`; all need `n_mb >= 2·pp`).
    MicrobatchShape,
    /// The cluster cannot host the topology under the candidate's
    /// group-assignment order (group capacities, DP replicas included).
    ClusterShape,
    /// Predicted peak memory exceeds the per-device cap.
    Memory,
    /// Theory-estimate throughput too far below the best candidate.
    TheoryBound,
}

impl Reject {
    /// Stable diagnostic label (CLI `--verbose` reject tallies).
    pub fn name(self) -> &'static str {
        match self {
            Reject::TpShape => "tp-shape",
            Reject::PipelineShape => "pipeline-shape",
            Reject::MicrobatchShape => "microbatch-shape",
            Reject::ClusterShape => "cluster-shape",
            Reject::Memory => "memory",
            Reject::TheoryBound => "theory-bound",
        }
    }

    /// Every rejection reason the stage-1 shape filter can produce, in
    /// tally order.
    pub const SHAPE_KINDS: [Reject; 4] =
        [Reject::TpShape, Reject::PipelineShape, Reject::MicrobatchShape, Reject::ClusterShape];
}

/// Check everything that can be decided without a cost model.
pub fn admissible(model: &PlanModel, cluster: &ClusterSpec, c: &Candidate) -> Result<(), Reject> {
    let lm = model.lm();
    // Megatron TP sharding: attention heads (Q and KV) and the SwiGLU
    // width must split evenly across TP ranks.
    if lm.q_heads % c.tp != 0 || lm.kv_heads % c.tp != 0 || lm.ffn % c.tp != 0 {
        return Err(Reject::TpShape);
    }
    if let PlanModel::Mllm(m) = model {
        if m.vit.heads % c.tp != 0 || m.vit.hidden % c.tp != 0 {
            return Err(Reject::TpShape);
        }
    }

    // Pipeline split must be realizable by the partitioner.
    let chunks = c.pp * c.vpp();
    if chunks < model.min_chunks() || chunks > model.max_chunks() {
        return Err(Reject::PipelineShape);
    }

    // Microbatch shape: 1F1B-I's interleaving constraint, and a uniform
    // `n_mb >= 2·pp` floor so every generator has a real steady state.
    if c.n_mb < 2 * c.pp {
        return Err(Reject::MicrobatchShape);
    }
    if c.kind == ScheduleKind::OneF1BInterleaved && c.n_mb % c.pp != 0 {
        return Err(Reject::MicrobatchShape);
    }

    // The pool must host the topology. Mapped candidates pin each replica
    // class's stages onto explicit node groups, so capacity is checked
    // against the map; everything else must resolve an ordinary
    // `device_view` (every stage's tp·cp·dp block inside one group).
    match c.map.as_deref() {
        Some(map) => map_admissible(cluster, c, map)?,
        None => {
            if cluster.device_view(&c.topo(), c.order).is_none() {
                return Err(Reject::ClusterShape);
            }
        }
    }
    Ok(())
}

/// Structural + capacity validation of an explicit stage→group map: every
/// class row covers the pp stages with in-range group indices, the class
/// widths are positive and sum to `dp`, and no node group is asked for
/// more GPUs than it has (an unbounded group — 0 nodes — hosts anything).
fn map_admissible(
    cluster: &ClusterSpec,
    c: &Candidate,
    map: &super::space::StageMap,
) -> Result<(), Reject> {
    let n_groups = cluster.groups.len();
    if map.rows.is_empty()
        || map.rows.len() != map.dp_widths.len()
        || map.dp_widths.iter().any(|&w| w == 0)
        || map.dp_widths.iter().sum::<usize>() != c.dp
        || map.rows.iter().any(|row| row.len() != c.pp)
        || map.rows.iter().flatten().any(|&g| g >= n_groups)
    {
        return Err(Reject::ClusterShape);
    }
    let topo = c.topo();
    for (g, group) in cluster.groups.iter().enumerate() {
        let cap = group.devices();
        if cap == 0 {
            continue; // unbounded group
        }
        let demand: usize = map
            .rows
            .iter()
            .zip(&map.dp_widths)
            .map(|(row, w)| {
                row.iter().filter(|&&rg| rg == g).count() * c.tp * topo.cp * w
            })
            .sum();
        if demand > cap {
            return Err(Reject::ClusterShape);
        }
    }
    Ok(())
}

/// Closed-form peak memory (bytes) for a candidate under its cost model:
/// Table 1's activation peak in units of the hottest chunk's `M_a`, plus
/// the static (weights + grads + optimizer + runtime) bytes.
///
/// Because this backs a *hard* pre-filter, it must never overestimate
/// what a schedule could achieve: `StpOffload` exists precisely to shrink
/// STP's `3p` peak by moving descending-leg activations to the host, so
/// it is priced at the `2p` floor its offload can approach (paper §4.4) —
/// the simulator then decides its real feasibility.
pub fn predicted_peak_bytes(cost: &CostModel, kind: ScheduleKind, n_mb: usize) -> usize {
    let ti = cost.theory_inputs(n_mb);
    let row = theory(kind, &ti);
    let peak_ma = if kind == ScheduleKind::StpOffload {
        row.peak_act_ma.min(2.0 * cost.topo.pp as f64)
    } else {
        row.peak_act_ma
    };
    let ma = cost.act_bytes.iter().copied().max().unwrap_or(0) as f64;
    // Table 1 states peaks in half-device (vpp = 2) `M_a` units — the
    // single-chunk rows (OneF1B/ZB-H1, and vpp-overridden generics at
    // vpp = 1) read "2p" with chunks of 2x size. Their cost models carry
    // full-device chunks, so halve the unit to match or the filter would
    // double-count and falsely reject feasible candidates.
    let ma_unit = if cost.topo.vpp == 1 { ma / 2.0 } else { ma };
    cost.static_bytes + (peak_ma * ma_unit) as usize
}

/// Memory pre-filter: predicted peak must fit the cap.
pub fn memory_feasible(
    cost: &CostModel,
    kind: ScheduleKind,
    n_mb: usize,
    cap_bytes: usize,
) -> bool {
    predicted_peak_bytes(cost, kind, n_mb) <= cap_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GroupOrder, HardwareProfile};
    use crate::model::ModelConfig;
    use crate::schedule::{OffloadParams, Placement};

    fn cand(tp: usize, pp: usize, dp: usize, kind: ScheduleKind, n_mb: usize) -> Candidate {
        Candidate {
            id: 0,
            tp,
            pp,
            dp,
            kind,
            n_mb,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: crate::sim::AcMode::None,
            map: None,
            vpp_gene: 0,
        }
    }

    fn a800() -> ClusterSpec {
        ClusterSpec::uniform(HardwareProfile::a800())
    }

    #[test]
    fn tp_divisibility_enforced() {
        let m = PlanModel::Llm(ModelConfig::qwen2_12b()); // 40 Q / 8 KV heads
        assert!(admissible(&m, &a800(), &cand(8, 2, 1, ScheduleKind::Stp, 64)).is_ok());
        assert_eq!(
            admissible(&m, &a800(), &cand(16, 1, 1, ScheduleKind::Stp, 64)),
            Err(Reject::TpShape)
        );
    }

    #[test]
    fn pipeline_depth_bounded_by_layers() {
        let m = PlanModel::Llm(ModelConfig::tiny_100m()); // 20 layers
        // pp=16 with vpp=2 needs 32 chunks > 20 layers.
        assert_eq!(
            admissible(&m, &a800(), &cand(1, 16, 1, ScheduleKind::Stp, 64)),
            Err(Reject::PipelineShape)
        );
        assert!(admissible(&m, &a800(), &cand(1, 8, 1, ScheduleKind::Stp, 64)).is_ok());
    }

    #[test]
    fn interleaved_needs_mb_multiple_of_pp() {
        let m = PlanModel::Llm(ModelConfig::qwen2_12b());
        assert_eq!(
            admissible(&m, &a800(), &cand(2, 3, 1, ScheduleKind::OneF1BInterleaved, 8)),
            Err(Reject::MicrobatchShape)
        );
        assert!(
            admissible(&m, &a800(), &cand(2, 3, 1, ScheduleKind::OneF1BInterleaved, 9)).is_ok()
        );
    }

    #[test]
    fn everyone_needs_two_pp_rounds_of_microbatches() {
        let m = PlanModel::Llm(ModelConfig::qwen2_12b());
        assert_eq!(
            admissible(&m, &a800(), &cand(2, 8, 1, ScheduleKind::Stp, 8)),
            Err(Reject::MicrobatchShape)
        );
    }

    #[test]
    fn cluster_capacity_enforced_on_mixed_pools() {
        let m = PlanModel::Llm(ModelConfig::qwen2_12b());
        let mixed = ClusterSpec::mixed_a800_h20(); // 8 + 8 GPUs
        assert!(admissible(&m, &mixed, &cand(8, 2, 1, ScheduleKind::Stp, 64)).is_ok());
        // A 16-GPU stage cannot fit inside either 8-GPU group.
        assert_eq!(
            admissible(&m, &mixed, &cand(8, 2, 2, ScheduleKind::Stp, 64)),
            Err(Reject::ClusterShape)
        );
        // The unbounded uniform pool hosts anything.
        assert!(admissible(&m, &a800(), &cand(8, 2, 2, ScheduleKind::Stp, 64)).is_ok());
    }

    #[test]
    fn memory_prefilter_orders_like_table1() {
        // ZB-V (2p·M_a) predicts less than STP (3p·M_a) on the same cost
        // model, and a tiny cap rejects both.
        let m = ModelConfig::qwen2_12b();
        let c = cand(4, 4, 1, ScheduleKind::Stp, 32);
        let cost = PlanModel::Llm(m).cost_model(
            &c.topo(),
            &a800(),
            GroupOrder::Declared,
            Placement::VShape,
            4096,
            0,
            1,
        );
        let stp = predicted_peak_bytes(&cost, ScheduleKind::Stp, 32);
        let zbv = predicted_peak_bytes(&cost, ScheduleKind::ZbV, 32);
        assert!(zbv < stp);
        assert!(!memory_feasible(&cost, ScheduleKind::Stp, 32, 1 << 30));
        assert!(memory_feasible(&cost, ScheduleKind::ZbV, 32, usize::MAX));
    }

    #[test]
    fn mllm_constraints_respect_vit() {
        let m = PlanModel::Mllm(crate::model::MllmConfig::qwen2vl_14_9b()); // 16 ViT heads
        assert!(admissible(&m, &a800(), &cand(8, 2, 1, ScheduleKind::Stp, 64)).is_ok());
        // MLLM needs at least 2 chunks: pp=1 with vpp=1 kinds has 1.
        assert_eq!(
            admissible(&m, &a800(), &cand(8, 1, 2, ScheduleKind::OneF1B, 64)),
            Err(Reject::PipelineShape)
        );
    }
}
