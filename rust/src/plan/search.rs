//! The planner's search loop: enumerate → prune → simulate in parallel →
//! rank.
//!
//! Pruning happens in three deterministic stages before any schedule is
//! built: (1) shape admissibility (TP divisibility, pipeline depth,
//! microbatch constraints), (2) the closed-form memory pre-filter
//! (Table-1 peak vs the cap), (3) a theory-estimate bound that drops
//! candidates whose predicted throughput is hopeless relative to the best
//! prediction — while always keeping the `min_keep` best-predicted so the
//! simulated field stays wide. Survivors are simulated concurrently on a
//! thread pool (the simulator replays ≥10^5 ops/s, so hundreds of
//! candidates rank in seconds) and sorted feasible-first by simulated
//! throughput. Results are bit-identical across runs and thread counts.

use std::collections::BTreeMap;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc;

use crate::cluster::ClusterSpec;
use crate::schedule::{OffloadParams, ScheduleKind};
use crate::sim::CostModel;

use super::constraints::{admissible, memory_feasible};
use super::evaluate::{estimated_throughput, evaluate, EvalContext, Evaluation};
use super::report::PlanReport;
use super::space::{enumerate, Candidate, PlanModel};

/// A planning request: model + device pool + GPU budget, plus the knobs
/// of the candidate space. `PlanQuery::new` fills paper-grade defaults;
/// override fields before calling [`plan`].
#[derive(Debug, Clone)]
pub struct PlanQuery {
    pub model: PlanModel,
    /// The device pool — `ClusterSpec::uniform(hw)` for the classic
    /// single-profile search, or a mixed spec whose group orderings the
    /// planner then enumerates.
    pub cluster: ClusterSpec,
    /// Total GPU budget (TP·PP·DP must equal it exactly).
    pub gpus: usize,
    /// Global memory-cap override, GiB (defaults to the pool's largest
    /// per-device capacity; per-device profile caps are always enforced
    /// by the simulated OOM check on top of this).
    pub mem_cap_gib: f64,
    pub seq: usize,
    pub mb_size: usize,
    /// ViT patch tokens per sample (MLLM models only).
    pub vit_tokens: usize,
    /// Microbatch counts to sweep (per DP replica).
    pub n_mb_options: Vec<usize>,
    /// Offload parameter variants (multiply the `StpOffload` kind).
    pub offload_variants: Vec<OffloadParams>,
    pub kinds: Vec<ScheduleKind>,
    /// Worker threads for candidate simulation (0 = all available cores).
    pub threads: usize,
    /// Theory-bound pruning: keep candidates predicted within
    /// `prune_slack · best_estimate`.
    pub prune_slack: f64,
    /// Always simulate at least this many best-predicted candidates.
    pub min_keep: usize,
}

impl PlanQuery {
    pub fn new(model: PlanModel, cluster: ClusterSpec, gpus: usize) -> PlanQuery {
        let mem_cap_gib = cluster.max_mem_gib();
        PlanQuery {
            model,
            cluster,
            gpus,
            mem_cap_gib,
            seq: 6144,
            mb_size: 1,
            vit_tokens: 3136,
            // Small counts keep GPipe's 2m·M_a peak in play; large counts
            // amortize the bubbles of the steady-state schedules.
            n_mb_options: vec![8, 16, 32, 64, 128],
            offload_variants: vec![
                OffloadParams::default(),
                // More aggressive host offload: bigger steady-phase slice.
                OffloadParams { alpha_warmup: 0.5, alpha_steady: 0.9, reload_lead: 2 },
            ],
            kinds: ScheduleKind::all().to_vec(),
            threads: 0,
            prune_slack: 0.5,
            min_keep: 192,
        }
    }

    pub fn mem_cap_bytes(&self) -> usize {
        (self.mem_cap_gib * (1u64 << 30) as f64) as usize
    }

    pub fn eval_context(&self) -> EvalContext {
        EvalContext {
            model: self.model.clone(),
            cluster: self.cluster.clone(),
            mem_cap_bytes: self.mem_cap_bytes(),
            seq: self.seq,
            vit_tokens: self.vit_tokens,
            mb_size: self.mb_size,
        }
    }

    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Run the full search and return the ranked report.
pub fn plan(q: &PlanQuery) -> PlanReport {
    let ctx = q.eval_context();
    let orders = q.cluster.group_orders();
    let all = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants);
    let n_enumerated = all.len();

    // Stage 1: shape admissibility (TP divisibility, pipeline depth,
    // microbatch rules, cluster capacity under the candidate's order).
    let mut shaped: Vec<Candidate> = Vec::with_capacity(all.len());
    let mut n_rejected_shape = 0;
    for c in &all {
        match admissible(&q.model, &q.cluster, c) {
            Ok(()) => shaped.push(*c),
            Err(_) => n_rejected_shape += 1,
        }
    }

    // Stage 2+3: memory pre-filter and theory estimates. The cost model
    // depends on (tp, pp, dp, vpp, order, placement) — cache it per key.
    // On mixed pools the group order and the schedule family's placement
    // change which device a chunk is costed against, and DP changes how
    // many GPUs a stage consumes (and so which group it lands in).
    let mut cost_cache: BTreeMap<(usize, usize, usize, usize, u8, u8), CostModel> =
        BTreeMap::new();
    let mut scored: Vec<(Candidate, f64)> = Vec::with_capacity(shaped.len());
    let mut n_pruned_memory = 0;
    for c in shaped {
        let key = (c.tp, c.pp, c.dp, c.vpp(), c.order as u8, c.placement() as u8);
        let cost = cost_cache.entry(key).or_insert_with(|| ctx.cost_model(&c));
        if !memory_feasible(cost, c.kind, c.n_mb, ctx.mem_cap_bytes) {
            n_pruned_memory += 1;
            continue;
        }
        scored.push((c, estimated_throughput(&ctx, cost, &c)));
    }

    let best_est = scored.iter().map(|x| x.1).fold(0.0f64, f64::max);
    let mut order: Vec<usize> = (0..scored.len()).collect();
    order.sort_by(|&a, &b| {
        scored[b]
            .1
            .partial_cmp(&scored[a].1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(scored[a].0.id.cmp(&scored[b].0.id))
    });
    let mut keep = vec![false; scored.len()];
    for (rank, &i) in order.iter().enumerate() {
        if rank < q.min_keep || scored[i].1 >= q.prune_slack * best_est {
            keep[i] = true;
        }
    }
    let mut survivors: Vec<Candidate> = Vec::with_capacity(scored.len());
    for (i, x) in scored.iter().enumerate() {
        if keep[i] {
            survivors.push(x.0);
        }
    }
    let n_pruned_theory = scored.len() - survivors.len();

    // Stage 4: simulate survivors on the thread pool. Work is claimed via
    // an atomic cursor; results carry their candidate and are re-sorted,
    // so the outcome is independent of thread interleaving.
    let evals = evaluate_parallel(&ctx, &survivors, q.effective_threads());

    let mut ranked = evals;
    ranked.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(b.throughput.partial_cmp(&a.throughput).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.candidate.id.cmp(&b.candidate.id))
    });

    PlanReport {
        model_name: q.model.name().to_string(),
        cluster_name: q.cluster.name.clone(),
        gpus: q.gpus,
        mem_cap_bytes: q.mem_cap_bytes(),
        seq: q.seq,
        mb_size: q.mb_size,
        n_enumerated,
        n_rejected_shape,
        n_pruned_memory,
        n_pruned_theory,
        ranked,
    }
}

/// Evaluate candidates concurrently; deterministic regardless of thread
/// count (exposed for the `plan_search` bench's scaling measurement).
pub fn evaluate_parallel(
    ctx: &EvalContext,
    candidates: &[Candidate],
    threads: usize,
) -> Vec<Evaluation> {
    let n_threads = threads.max(1).min(candidates.len().max(1));
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Evaluation>();
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let tx = tx.clone();
            let cursor = &cursor;
            s.spawn(move || loop {
                let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= candidates.len() {
                    break;
                }
                if tx.send(evaluate(ctx, &candidates[i])).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);
    let mut out: Vec<Evaluation> = rx.into_iter().collect();
    out.sort_by_key(|e| e.candidate.id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HardwareProfile;
    use crate::model::ModelConfig;

    fn small_query() -> PlanQuery {
        let mut q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            ClusterSpec::uniform(HardwareProfile::a800()),
            8,
        );
        q.seq = 2048;
        q.n_mb_options = vec![8, 16];
        q.threads = 2;
        q
    }

    #[test]
    fn funnel_counts_are_consistent() {
        let q = small_query();
        let r = plan(&q);
        assert_eq!(
            r.n_enumerated,
            r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.ranked.len()
        );
        assert!(r.best().is_some(), "8 GPUs must admit a feasible plan");
    }

    #[test]
    fn ranking_is_feasible_first_and_monotone() {
        let r = plan(&small_query());
        let mut seen_infeasible = false;
        let mut last = f64::INFINITY;
        for e in &r.ranked {
            if !e.feasible {
                seen_infeasible = true;
                continue;
            }
            assert!(!seen_infeasible, "feasible candidate ranked after infeasible");
            assert!(e.throughput <= last + 1e-12);
            last = e.throughput;
        }
    }

    #[test]
    fn parallel_evaluation_matches_serial() {
        let q = small_query();
        let ctx = q.eval_context();
        let orders = q.cluster.group_orders();
        let all = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants);
        let survivors: Vec<Candidate> = all
            .into_iter()
            .filter(|c| admissible(&q.model, &q.cluster, c).is_ok())
            .filter(|c| {
                let cost = ctx.cost_model(c);
                memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes)
            })
            .take(12)
            .collect();
        let serial = evaluate_parallel(&ctx, &survivors, 1);
        let parallel = evaluate_parallel(&ctx, &survivors, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.candidate.id, b.candidate.id);
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.peak_mem_bytes, b.peak_mem_bytes);
        }
    }
}
