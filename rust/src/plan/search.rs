//! The planner's search loop: enumerate → prune → simulate in parallel →
//! rank.
//!
//! Pruning happens in three deterministic stages before any schedule is
//! built: (1) shape admissibility (TP divisibility, pipeline depth,
//! microbatch constraints), (2) the closed-form memory pre-filter
//! (Table-1 peak vs the cap), (3) a theory-estimate bound that drops
//! candidates whose predicted throughput is hopeless relative to the best
//! prediction — while always keeping the `min_keep` best-predicted so the
//! simulated field stays wide. Survivors are simulated concurrently on a
//! thread pool (each worker reuses one [`SimArena`], the no-trace
//! event-driven replay) and sorted feasible-first by simulated
//! throughput. Results are bit-identical across runs and thread counts.
//!
//! For budgets where exhaustive simulation stops scaling (hundreds of
//! GPUs — the group orderings multiply the space further), a
//! **beam search** ([`SearchMode::Beam`]) replaces stage 3+4: the beam is
//! seeded from the theory estimates (top-`width` overall plus the best
//! prediction per schedule kind), then repeatedly expands the simulated
//! frontier to the neighbors of the current beam in
//! (tp, pp, n_mb, order) space, stopping when a whole frontier round
//! fails to improve the best simulated plan. Everything is ordered by
//! (estimate, candidate id), so beam results are as deterministic as the
//! exhaustive ones.
//!
//! [`SearchMode::Evo`] goes further: a seeded evolutionary search (the
//! [`super::evo`] module) whose genome also spans activation
//! checkpointing, virtual-pipeline overrides and explicit stage→group
//! maps — co-optimization axes the enumerated space never visits. Its
//! fitness passes run through the same [`evaluate_batch`] pipeline, so
//! evo inherits the memoization and thread-count determinism for free.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc;

use crate::cluster::ClusterSpec;
use crate::schedule::{OffloadParams, ScheduleKind};
use crate::sim::{SimArena, SimMode};

use super::cache::{CostMemo, EvalKey, EvalMemo};
use super::constraints::{admissible, memory_feasible, Reject};
use super::evaluate::{estimated_throughput, evaluate_in_memo, EvalContext, Evaluation};
use super::report::PlanReport;
use super::space::{enumerate, Candidate, PlanModel};

/// Hard cap on beam rounds (a backstop far above any observed run; the
/// stall rule terminates long before).
const BEAM_MAX_ROUNDS: usize = 64;

/// How the planner explores the candidate space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Simulate every candidate that survives the memory pre-filter and
    /// the theory bound (the historical behavior).
    Exhaustive,
    /// Theory-seeded beam search over (tp, pp, n_mb, order) neighbors.
    Beam {
        /// Beam width: candidates simulated per frontier round.
        width: usize,
    },
    /// Evolutionary search over the full co-optimization space —
    /// schedule kind, (tp, pp, dp, vpp, n_mb), group order, offload
    /// variant, activation checkpointing, and (on mixed pools) explicit
    /// stage→group placements with per-class DP widths (DESIGN.md §16).
    Evo {
        /// Evolution rounds after the seed generation.
        generations: usize,
        /// Individuals carried between rounds (and offspring per round).
        population: usize,
        /// RNG seed — same seed, same report, at any thread count.
        seed: u64,
    },
}

impl SearchMode {
    /// Stable label for reports and JSON ("exhaustive", "beam-8",
    /// "evo-12-24-42"). The evo label carries every search parameter, so
    /// `canonical_key` distinguishes evo budgets for free.
    pub fn label(&self) -> String {
        match self {
            SearchMode::Exhaustive => "exhaustive".to_string(),
            SearchMode::Beam { width } => format!("beam-{width}"),
            SearchMode::Evo { generations, population, seed } => {
                format!("evo-{generations}-{population}-{seed}")
            }
        }
    }
}

/// A planning request: model + device pool + GPU budget, plus the knobs
/// of the candidate space. `PlanQuery::new` fills paper-grade defaults;
/// override fields before calling [`plan`].
#[derive(Debug, Clone)]
pub struct PlanQuery {
    pub model: PlanModel,
    /// The device pool — `ClusterSpec::uniform(hw)` for the classic
    /// single-profile search, or a mixed spec whose group orderings the
    /// planner then enumerates.
    pub cluster: ClusterSpec,
    /// Total GPU budget (TP·PP·DP must equal it exactly).
    pub gpus: usize,
    /// Global memory-cap override, GiB (defaults to the pool's largest
    /// per-device capacity; per-device profile caps are always enforced
    /// by the simulated OOM check on top of this).
    pub mem_cap_gib: f64,
    pub seq: usize,
    pub mb_size: usize,
    /// ViT patch tokens per sample (MLLM models only).
    pub vit_tokens: usize,
    /// Microbatch counts to sweep (per DP replica).
    pub n_mb_options: Vec<usize>,
    /// Offload parameter variants (multiply the `StpOffload` kind).
    pub offload_variants: Vec<OffloadParams>,
    pub kinds: Vec<ScheduleKind>,
    /// Worker threads for candidate simulation (0 = all available cores).
    pub threads: usize,
    /// Theory-bound pruning: keep candidates predicted within
    /// `prune_slack · best_estimate`.
    pub prune_slack: f64,
    /// Always simulate at least this many best-predicted candidates.
    pub min_keep: usize,
    /// Exploration strategy (exhaustive by default; beam for large
    /// budgets).
    pub search: SearchMode,
    /// Replica replay strategy: symmetry-folded (default, fleet-scale
    /// dp is free) or the full per-replica sweep (the bench baseline).
    /// Results are bit-identical either way.
    pub sim: SimMode,
}

impl PlanQuery {
    pub fn new(model: PlanModel, cluster: ClusterSpec, gpus: usize) -> PlanQuery {
        let mem_cap_gib = cluster.max_mem_gib();
        PlanQuery {
            model,
            cluster,
            gpus,
            mem_cap_gib,
            seq: 6144,
            mb_size: 1,
            vit_tokens: 3136,
            // Small counts keep GPipe's 2m·M_a peak in play; large counts
            // amortize the bubbles of the steady-state schedules.
            n_mb_options: vec![8, 16, 32, 64, 128],
            offload_variants: vec![
                OffloadParams::default(),
                // More aggressive host offload: bigger steady-phase slice.
                OffloadParams { alpha_warmup: 0.5, alpha_steady: 0.9, reload_lead: 2 },
            ],
            kinds: ScheduleKind::all().to_vec(),
            threads: 0,
            prune_slack: 0.5,
            min_keep: 192,
            search: SearchMode::Exhaustive,
            sim: SimMode::Folded,
        }
    }

    pub fn mem_cap_bytes(&self) -> usize {
        (self.mem_cap_gib * (1u64 << 30) as f64) as usize
    }

    pub fn eval_context(&self) -> EvalContext {
        EvalContext {
            model: self.model.clone(),
            cluster: self.cluster.clone(),
            mem_cap_bytes: self.mem_cap_bytes(),
            seq: self.seq,
            vit_tokens: self.vit_tokens,
            mb_size: self.mb_size,
            sim: self.sim,
        }
    }

    fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Run the full search and return the ranked report.
pub fn plan(q: &PlanQuery) -> PlanReport {
    plan_with_memo(q, None)
}

/// [`plan`] with an optional cross-query evaluation memo (the
/// [`super::cache::PlanCache`] threads one through). Memo hits skip the
/// replay but still enter the ranked list, so the funnel counters and
/// the report bytes are identical to a cold search.
pub fn plan_with_memo(q: &PlanQuery, memo: Option<&mut EvalMemo>) -> PlanReport {
    let ctx = q.eval_context();
    let orders = q.cluster.group_orders();
    let all = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants);
    // Evolutionary search grows the population beyond the enumerated
    // space (novel genomes: AC modes, vpp overrides, stage maps), so the
    // total is mutable — every novel genome lands in exactly one funnel
    // bucket and the invariant below still balances.
    let mut n_enumerated = all.len();

    // Stage 1: shape admissibility (TP divisibility, pipeline depth,
    // microbatch rules, cluster capacity under the candidate's order).
    let mut shaped: Vec<Candidate> = Vec::with_capacity(all.len());
    let mut n_rejected_shape = 0;
    let mut shape_reject_tallies: Vec<(Reject, usize)> =
        Reject::SHAPE_KINDS.iter().map(|&r| (r, 0)).collect();
    for c in &all {
        match admissible(&q.model, &q.cluster, c) {
            Ok(()) => shaped.push(c.clone()),
            Err(r) => {
                n_rejected_shape += 1;
                if let Some(t) = shape_reject_tallies.iter_mut().find(|(k, _)| *k == r) {
                    t.1 += 1;
                }
            }
        }
    }

    // Stage 2+3: memory pre-filter and theory estimates. The cost model
    // depends on (tp, pp, dp, vpp, order, placement) — the CostMemo
    // builds each shape once and stage 4 reuses the same models (and
    // their fingerprints) for simulation and eval memoization. On mixed
    // pools the group order and the schedule family's placement change
    // which device a chunk is costed against, and DP changes how many
    // GPUs a stage consumes (and so which group it lands in).
    let mut costs = CostMemo::new();
    let mut scored: Vec<(Candidate, f64)> = Vec::with_capacity(shaped.len());
    let mut n_pruned_memory = 0;
    for c in shaped {
        let (cost, _fp) = costs.get_or_build(&ctx, &c);
        if !memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes) {
            n_pruned_memory += 1;
            continue;
        }
        let est = estimated_throughput(&ctx, &cost, &c);
        scored.push((c, est));
    }

    // Stage 4: simulate — every theory-bound survivor (exhaustive) or
    // the beam's frontier walk. Work is claimed via an atomic cursor;
    // results carry their candidate and are re-sorted, so the outcome is
    // independent of thread interleaving.
    let threads = q.effective_threads();
    let evals = match q.search {
        SearchMode::Exhaustive => {
            let best_est = scored.iter().map(|x| x.1).fold(0.0f64, f64::max);
            let mut order: Vec<usize> = (0..scored.len()).collect();
            order.sort_by(|&a, &b| {
                scored[b]
                    .1
                    .partial_cmp(&scored[a].1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(scored[a].0.id.cmp(&scored[b].0.id))
            });
            let mut keep = vec![false; scored.len()];
            for (rank, &i) in order.iter().enumerate() {
                if rank < q.min_keep || scored[i].1 >= q.prune_slack * best_est {
                    keep[i] = true;
                }
            }
            let mut survivors: Vec<Candidate> = Vec::with_capacity(scored.len());
            for (i, x) in scored.iter().enumerate() {
                if keep[i] {
                    survivors.push(x.0.clone());
                }
            }
            evaluate_batch(&ctx, &survivors, threads, &mut costs, memo)
        }
        SearchMode::Beam { width } => {
            beam_evaluate(&ctx, &scored, width, threads, &mut costs, memo)
        }
        SearchMode::Evo { generations, population, seed } => {
            let out = super::evo::evolve(
                &ctx,
                q,
                &scored,
                n_enumerated,
                generations,
                population,
                seed,
                threads,
                &mut costs,
                memo,
            );
            n_enumerated += out.generated;
            for (r, n) in out.shape_rejects {
                n_rejected_shape += n;
                if let Some(t) = shape_reject_tallies.iter_mut().find(|(k, _)| *k == r) {
                    t.1 += n;
                }
            }
            n_pruned_memory += out.pruned_memory;
            out.evals
        }
    };
    // Universal funnel identity: whatever the strategy left unsimulated
    // counts as theory-pruned. For exhaustive/beam this reduces to the
    // historical `scored.len() - evals.len()`; for evo it also absorbs
    // the scored-but-never-visited part of the enumerated space.
    let n_pruned_theory = n_enumerated - n_rejected_shape - n_pruned_memory - evals.len();

    let mut ranked = evals;
    ranked.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(b.throughput.partial_cmp(&a.throughput).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.candidate.id.cmp(&b.candidate.id))
    });

    // The executable handoff for the winner (`stp plan --emit-plan`,
    // `stp train --plan`).
    let best_artifact = ranked
        .first()
        .filter(|e| e.feasible)
        .map(|e| super::artifact::PlanArtifact::for_evaluation(&ctx, e));

    PlanReport {
        model_name: q.model.name().to_string(),
        cluster_name: q.cluster.name.clone(),
        gpus: q.gpus,
        mem_cap_bytes: q.mem_cap_bytes(),
        seq: q.seq,
        mb_size: q.mb_size,
        search_mode: q.search.label(),
        n_enumerated,
        n_rejected_shape,
        shape_reject_tallies,
        n_pruned_memory,
        n_pruned_theory,
        ranked,
        best_artifact,
    }
}

/// Candidate coordinates the beam moves along: dp is implied by
/// (tp, pp) and the budget, so neighbors vary tp, pp, n_mb and the
/// group order one step at a time; kind and offload variant are fixed
/// per beam member (the seeding covers every kind).
type BeamKey = (usize, usize, usize, u8, u8, usize);

fn beam_key(c: &Candidate) -> BeamKey {
    (c.tp, c.pp, c.n_mb, c.order as u8, c.kind as u8, c.offload_variant)
}

/// Values adjacent to `v` in the sorted distinct list `vals`.
fn adjacent(vals: &[usize], v: usize) -> Vec<usize> {
    match vals.binary_search(&v) {
        Ok(i) => {
            let mut out = Vec::with_capacity(2);
            if i > 0 {
                out.push(vals[i - 1]);
            }
            if i + 1 < vals.len() {
                out.push(vals[i + 1]);
            }
            out
        }
        Err(_) => Vec::new(),
    }
}

/// Beam search over the scored (memory-feasible, theory-estimated)
/// candidates: seed from the estimates, expand (tp, pp, n_mb, order)
/// neighbors of the current beam, stop when a frontier round stops
/// improving the best simulated plan. Returns every simulated
/// evaluation (the caller ranks them like the exhaustive path).
fn beam_evaluate(
    ctx: &EvalContext,
    scored: &[(Candidate, f64)],
    width: usize,
    threads: usize,
    costs: &mut CostMemo,
    mut memo: Option<&mut EvalMemo>,
) -> Vec<Evaluation> {
    if scored.is_empty() {
        return Vec::new();
    }
    let width = width.max(1);

    let index: BTreeMap<BeamKey, usize> =
        scored.iter().enumerate().map(|(i, (c, _))| (beam_key(c), i)).collect();

    // Distinct move coordinates actually present in the space.
    let sorted_unique = |mut v: Vec<usize>| {
        v.sort_unstable();
        v.dedup();
        v
    };
    let tps = sorted_unique(scored.iter().map(|(c, _)| c.tp).collect());
    let pps = sorted_unique(scored.iter().map(|(c, _)| c.pp).collect());
    let mbs = sorted_unique(scored.iter().map(|(c, _)| c.n_mb).collect());
    let orders = {
        let mut v: Vec<u8> = scored.iter().map(|(c, _)| c.order as u8).collect();
        v.sort_unstable();
        v.dedup();
        v
    };

    // Estimate-descending order (ties broken by candidate id).
    let mut by_est: Vec<usize> = (0..scored.len()).collect();
    by_est.sort_by(|&a, &b| {
        scored[b]
            .1
            .partial_cmp(&scored[a].1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(scored[a].0.id.cmp(&scored[b].0.id))
    });

    // Seed: the top `width` predictions overall, plus the best prediction
    // of every schedule kind not already covered (so no family is written
    // off by its theory row alone).
    let mut seeds: Vec<usize> = by_est.iter().copied().take(width).collect();
    let mut kinds_seen: BTreeSet<u8> =
        seeds.iter().map(|&i| scored[i].0.kind as u8).collect();
    for &i in &by_est {
        let k = scored[i].0.kind as u8;
        if kinds_seen.insert(k) {
            seeds.push(i);
        }
    }

    let mut simulated: BTreeMap<usize, Evaluation> = BTreeMap::new();
    simulate_into(ctx, scored, &seeds, threads, costs, memo.as_deref_mut(), &mut simulated);

    // (feasible, throughput) with deterministic id tiebreak.
    let beam_rank = |a: &Evaluation, b: &Evaluation| {
        b.feasible
            .cmp(&a.feasible)
            .then(b.throughput.partial_cmp(&a.throughput).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.candidate.id.cmp(&b.candidate.id))
    };
    let best_of = |sims: &BTreeMap<usize, Evaluation>| -> (bool, f64) {
        sims.values()
            .fold((false, 0.0f64), |acc, e| {
                if (e.feasible, e.throughput) > acc { (e.feasible, e.throughput) } else { acc }
            })
    };
    let mut best = best_of(&simulated);

    for _round in 0..BEAM_MAX_ROUNDS {
        // Current beam: the top `width` simulated candidates.
        let mut ranked: Vec<&Evaluation> = simulated.values().collect();
        ranked.sort_by(|a, b| beam_rank(a, b));
        let beam: Vec<Candidate> =
            ranked.iter().take(width).map(|e| e.candidate.clone()).collect();

        // Frontier: unsimulated one-step neighbors of the beam.
        let mut frontier: BTreeSet<usize> = BTreeSet::new();
        for c in &beam {
            let mut keys: Vec<BeamKey> = Vec::new();
            for tp in adjacent(&tps, c.tp) {
                keys.push((tp, c.pp, c.n_mb, c.order as u8, c.kind as u8, c.offload_variant));
            }
            for pp in adjacent(&pps, c.pp) {
                keys.push((c.tp, pp, c.n_mb, c.order as u8, c.kind as u8, c.offload_variant));
            }
            for mb in adjacent(&mbs, c.n_mb) {
                keys.push((c.tp, c.pp, mb, c.order as u8, c.kind as u8, c.offload_variant));
            }
            for &o in &orders {
                if o != c.order as u8 {
                    keys.push((c.tp, c.pp, c.n_mb, o, c.kind as u8, c.offload_variant));
                }
            }
            for k in keys {
                if let Some(&i) = index.get(&k) {
                    if !simulated.contains_key(&i) {
                        frontier.insert(i);
                    }
                }
            }
        }
        let mut frontier: Vec<usize> = frontier.into_iter().collect();
        frontier.sort_by(|&a, &b| {
            scored[b]
                .1
                .partial_cmp(&scored[a].1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(scored[a].0.id.cmp(&scored[b].0.id))
        });
        frontier.truncate(width);
        if frontier.is_empty() {
            break;
        }

        simulate_into(ctx, scored, &frontier, threads, costs, memo.as_deref_mut(), &mut simulated);
        let new_best = best_of(&simulated);
        if new_best <= best {
            // The frontier stalled: no neighbor beat the incumbent plan.
            break;
        }
        best = new_best;
    }

    simulated.into_values().collect()
}

/// Evaluate a batch, consulting the cross-query memo first. Hits are
/// settled sequentially (relabeled with the requesting candidate);
/// only the misses hit the thread pool. Fresh evaluations are recorded
/// back under their (cost, context, coordinates) key. The returned
/// list is sorted by candidate id, exactly like [`evaluate_parallel`].
/// `pub(super)` so the evo module's per-generation fitness pass shares
/// the exact same memoized, thread-deterministic pipeline.
pub(super) fn evaluate_batch(
    ctx: &EvalContext,
    cands: &[Candidate],
    threads: usize,
    costs: &mut CostMemo,
    mut memo: Option<&mut EvalMemo>,
) -> Vec<Evaluation> {
    let mut out: Vec<Evaluation> = Vec::with_capacity(cands.len());
    let mut to_sim: Vec<Candidate> = Vec::new();
    if let Some(memo) = memo.as_deref_mut() {
        for c in cands {
            let (_, fp) = costs.get_or_build(ctx, c);
            let key = EvalKey::new(fp, ctx, c);
            match memo.lookup(&key, c) {
                Some(e) => out.push(e),
                None => to_sim.push(c.clone()),
            }
        }
    } else {
        to_sim.extend_from_slice(cands);
    }
    let fresh = evaluate_parallel_memo(ctx, &to_sim, threads, costs);
    if let Some(memo) = memo {
        for e in &fresh {
            let (_, fp) = costs.get_or_build(ctx, &e.candidate);
            memo.record(EvalKey::new(fp, ctx, &e.candidate), e.clone());
        }
    }
    out.extend(fresh);
    out.sort_by_key(|e| e.candidate.id);
    out
}

/// Simulate the `scored` entries at `idxs` (beam seeds or a frontier)
/// and insert the evaluations into `simulated` keyed by index.
/// [`evaluate_batch`] returns evaluations sorted by candidate id and
/// `scored` is in enumeration (id) order, so sorting the indices keeps
/// the zip aligned.
fn simulate_into(
    ctx: &EvalContext,
    scored: &[(Candidate, f64)],
    idxs: &[usize],
    threads: usize,
    costs: &mut CostMemo,
    memo: Option<&mut EvalMemo>,
    simulated: &mut BTreeMap<usize, Evaluation>,
) {
    let mut idxs: Vec<usize> = idxs.to_vec();
    idxs.sort_unstable();
    let cands: Vec<Candidate> = idxs.iter().map(|&i| scored[i].0.clone()).collect();
    for (i, e) in idxs.iter().zip(evaluate_batch(ctx, &cands, threads, costs, memo)) {
        simulated.insert(*i, e);
    }
}

/// Evaluate candidates concurrently; deterministic regardless of thread
/// count (exposed for the `plan_search` bench's scaling measurement).
/// Each worker owns one [`SimArena`], so a candidate evaluation reuses
/// the previous one's buffers instead of allocating.
pub fn evaluate_parallel(
    ctx: &EvalContext,
    candidates: &[Candidate],
    threads: usize,
) -> Vec<Evaluation> {
    evaluate_parallel_memo(ctx, candidates, threads, &CostMemo::new())
}

/// [`evaluate_parallel`] with a shared per-search cost-model memo:
/// workers reuse the models stage 2 already built instead of rebuilding
/// one per candidate (shapes repeat across kinds, n_mb and offload
/// variants, so most lookups hit).
pub fn evaluate_parallel_memo(
    ctx: &EvalContext,
    candidates: &[Candidate],
    threads: usize,
    costs: &CostMemo,
) -> Vec<Evaluation> {
    let n_threads = threads.max(1).min(candidates.len().max(1));
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Evaluation>();
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let tx = tx.clone();
            let cursor = &cursor;
            s.spawn(move || {
                let mut arena = SimArena::default();
                loop {
                    let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= candidates.len() {
                        break;
                    }
                    let e = evaluate_in_memo(ctx, &candidates[i], &mut arena, costs);
                    if tx.send(e).is_err() {
                        break;
                    }
                }
            });
        }
    });
    drop(tx);
    let mut out: Vec<Evaluation> = rx.into_iter().collect();
    out.sort_by_key(|e| e.candidate.id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::HardwareProfile;
    use crate::model::ModelConfig;

    fn small_query() -> PlanQuery {
        let mut q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            ClusterSpec::uniform(HardwareProfile::a800()),
            8,
        );
        q.seq = 2048;
        q.n_mb_options = vec![8, 16];
        q.threads = 2;
        q
    }

    #[test]
    fn funnel_counts_are_consistent() {
        let q = small_query();
        let r = plan(&q);
        assert_eq!(
            r.n_enumerated,
            r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.ranked.len()
        );
        assert!(r.best().is_some(), "8 GPUs must admit a feasible plan");
    }

    #[test]
    fn ranking_is_feasible_first_and_monotone() {
        let r = plan(&small_query());
        let mut seen_infeasible = false;
        let mut last = f64::INFINITY;
        for e in &r.ranked {
            if !e.feasible {
                seen_infeasible = true;
                continue;
            }
            assert!(!seen_infeasible, "feasible candidate ranked after infeasible");
            assert!(e.throughput <= last + 1e-12);
            last = e.throughput;
        }
    }

    #[test]
    fn parallel_evaluation_matches_serial() {
        let q = small_query();
        let ctx = q.eval_context();
        let orders = q.cluster.group_orders();
        let all = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants);
        let survivors: Vec<Candidate> = all
            .into_iter()
            .filter(|c| admissible(&q.model, &q.cluster, c).is_ok())
            .filter(|c| {
                let cost = ctx.cost_model(c);
                memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes)
            })
            .take(12)
            .collect();
        let serial = evaluate_parallel(&ctx, &survivors, 1);
        let parallel = evaluate_parallel(&ctx, &survivors, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.candidate.id, b.candidate.id);
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.peak_mem_bytes, b.peak_mem_bytes);
        }
    }

    #[test]
    fn beam_funnel_counts_stay_consistent() {
        let mut q = small_query();
        q.search = SearchMode::Beam { width: 4 };
        let r = plan(&q);
        assert_eq!(r.search_mode, "beam-4");
        assert_eq!(
            r.n_enumerated,
            r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.ranked.len()
        );
        assert!(r.best().is_some());
    }

    #[test]
    fn beam_is_deterministic_across_thread_counts() {
        let mut a = small_query();
        a.search = SearchMode::Beam { width: 4 };
        a.threads = 1;
        let mut b = a.clone();
        b.threads = 4;
        let ra = plan(&a);
        let rb = plan(&b);
        assert_eq!(ra.ranked.len(), rb.ranked.len());
        for (x, y) in ra.ranked.iter().zip(&rb.ranked) {
            assert_eq!(x.candidate.id, y.candidate.id);
            assert_eq!(x.throughput.to_bits(), y.throughput.to_bits());
        }
    }

    #[test]
    fn beam_simulates_fewer_but_finds_the_exhaustive_best() {
        let mut ex = small_query();
        ex.n_mb_options = vec![8, 16, 32];
        let mut beam = ex.clone();
        beam.search = SearchMode::Beam { width: 6 };
        let re = plan(&ex);
        let rb = plan(&beam);
        assert!(
            rb.n_simulated() < re.n_simulated(),
            "beam simulated {} !< exhaustive {}",
            rb.n_simulated(),
            re.n_simulated()
        );
        let eb = re.best().expect("exhaustive best");
        let bb = rb.best().expect("beam best");
        assert_eq!(eb.candidate.id, bb.candidate.id, "beam best != exhaustive best");
        assert_eq!(eb.throughput.to_bits(), bb.throughput.to_bits());
    }

    #[test]
    fn evo_funnel_counts_stay_consistent() {
        let mut q = small_query();
        q.search = SearchMode::Evo { generations: 3, population: 8, seed: 11 };
        let r = plan(&q);
        assert_eq!(r.search_mode, "evo-3-8-11");
        assert_eq!(
            r.n_enumerated,
            r.n_rejected_shape + r.n_pruned_memory + r.n_pruned_theory + r.ranked.len()
        );
        assert!(r.best().is_some(), "evo on 8 GPUs must land a feasible plan");
    }

    #[test]
    fn memoized_replan_is_byte_identical_and_reuses_evals() {
        let q = small_query();
        let cold = plan(&q);
        let mut memo = EvalMemo::new();
        let warm1 = plan_with_memo(&q, Some(&mut memo));
        let misses = memo.misses;
        assert!(misses > 0, "first memoized search must simulate");
        assert_eq!(memo.hits, 0);
        let warm2 = plan_with_memo(&q, Some(&mut memo));
        assert_eq!(memo.hits, misses, "second search must hit for every survivor");
        assert_eq!(memo.misses, misses, "second search must not re-simulate");
        let bytes = |r: &PlanReport| r.to_json().to_string();
        assert_eq!(bytes(&cold), bytes(&warm1));
        assert_eq!(bytes(&cold), bytes(&warm2));
    }

    #[test]
    fn beam_with_memo_is_byte_identical_to_cold_beam() {
        let mut q = small_query();
        q.search = SearchMode::Beam { width: 4 };
        let cold = plan(&q);
        let mut memo = EvalMemo::new();
        let w1 = plan_with_memo(&q, Some(&mut memo));
        let w2 = plan_with_memo(&q, Some(&mut memo));
        assert!(memo.hits > 0, "replayed beam search must hit the memo");
        assert_eq!(cold.to_json().to_string(), w1.to_json().to_string());
        assert_eq!(cold.to_json().to_string(), w2.to_json().to_string());
    }

    #[test]
    fn unfolded_search_is_byte_identical_to_folded() {
        let q = small_query();
        let mut uq = q.clone();
        uq.sim = SimMode::Unfolded;
        let folded = plan(&q);
        let unfolded = plan(&uq);
        assert_eq!(folded.to_json().to_string(), unfolded.to_json().to_string());
    }

    #[test]
    fn memoized_cost_models_do_not_change_evaluations() {
        let q = small_query();
        let ctx = q.eval_context();
        let orders = q.cluster.group_orders();
        let all = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants);
        let survivors: Vec<Candidate> = all
            .into_iter()
            .filter(|c| admissible(&q.model, &q.cluster, c).is_ok())
            .filter(|c| {
                let cost = ctx.cost_model(c);
                memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes)
            })
            .take(12)
            .collect();
        let mut costs = CostMemo::new();
        for c in &survivors {
            costs.get_or_build(&ctx, c);
        }
        let plain = evaluate_parallel(&ctx, &survivors, 2);
        let memoed = evaluate_parallel_memo(&ctx, &survivors, 2, &costs);
        assert_eq!(plain.len(), memoed.len());
        for (a, b) in plain.iter().zip(&memoed) {
            assert_eq!(a.candidate.id, b.candidate.id);
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.peak_mem_bytes, b.peak_mem_bytes);
        }
    }
}
