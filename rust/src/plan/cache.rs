//! The keyed plan cache and the per-search memos behind it
//! (DESIGN.md §15).
//!
//! Three layers, coarsest first:
//!
//! 1. [`PlanCache`] — exact-query memoization: a canonical key over
//!    model dims × [`ClusterSpec`] × budget × search mode maps to the
//!    stored [`PlanReport`](super::report::PlanReport) JSON, so a repeat
//!    what-if query is answered byte-identically without re-searching.
//! 2. [`EvalMemo`] — cross-query simulation reuse: evaluations are keyed
//!    by a fingerprint of the candidate's *resolved* cost content (unit
//!    timings, per-device profiles, per-hop P2P costs), not the raw
//!    query, so an incremental re-search after a cluster delta replays
//!    only the candidates whose resolved physics actually changed. Hits
//!    never alter the search trajectory — the searched set and ranking
//!    are those of a cold run, so reports stay byte-identical.
//! 3. [`CostMemo`] — the per-search cost-model memo (satellite perf
//!    fix): beam rounds and the exhaustive sweep share one `CostModel`
//!    per (tp, pp, dp, vpp, order, placement) instead of rebuilding it
//!    per candidate.
//!
//! All keys are content-derived (FNV-1a over canonical little-endian
//! bytes, `f64::to_bits` for floats) — no hasher randomization, so keys
//! are stable across processes and platforms.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::ClusterSpec;
use crate::sim::CostModel;

use super::evaluate::{EvalContext, Evaluation};
use super::search::{plan_with_memo, PlanQuery};
use super::space::{Candidate, StageMap};

/// FNV-1a, 64-bit: tiny, dependency-free, deterministic across runs.
struct Fnv64(u64);

impl Fnv64 {
    fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    fn write_usize(&mut self, v: usize) {
        self.write_u64(v as u64);
    }

    fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    fn write_str(&mut self, s: &str) {
        self.write_usize(s.len());
        for &b in s.as_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Fingerprint of everything the replay and the DP/MFU arithmetic read
/// from a [`CostModel`]: the *resolved* quantities (per-chunk unit
/// timings, per-device profiles, per-hop P2P costs, the group-ordered
/// FLOPs aggregation), not the raw [`ClusterSpec`]. Two cost models with
/// equal fingerprints replay bit-identically, so a cluster delta that
/// leaves a candidate's resolved devices untouched (say, a node group
/// its view never lands on) still reuses that candidate's evaluation.
pub fn cost_fingerprint(cost: &CostModel) -> u64 {
    let mut h = Fnv64::new();
    let t = &cost.topo;
    for v in [t.tp, t.pp, t.dp, t.cp, t.vpp, cost.mb_size, cost.p2p_bytes, cost.static_bytes] {
        h.write_usize(v);
    }
    h.write_f64(cost.w_frac);
    h.write_f64(cost.model_flops_per_sample);
    h.write_usize(cost.chunks.len());
    for cu in &cost.chunks {
        for units in [&cu.fwd, &cu.bwd, &cu.wgrad] {
            h.write_usize(units.len());
            for u in units {
                h.write_f64(u.compute);
                h.write_f64(u.ar);
                h.write_u64(u.stream as u64);
            }
        }
    }
    for &b in &cost.act_bytes {
        h.write_usize(b);
    }
    for &b in &cost.static_bytes_per_dev {
        h.write_usize(b);
    }
    for &d in &cost.chunk_dev {
        h.write_usize(d);
    }
    h.write_usize(cost.stage_plan.chunks.len());
    for ch in &cost.stage_plan.chunks {
        h.write_usize(ch.lm_layers);
        h.write_usize(ch.vit_layers);
        h.write_u64(ch.has_embed as u64);
        h.write_u64(ch.has_head as u64);
    }
    // Resolved device pool: per-PP-rank profile fields (compute, link
    // tiers, collective constants, PCIe, memory cap) and the uniformity
    // flag the DP gradient ring's span rule reads.
    let n_dev = cost.view.n_devices();
    h.write_usize(n_dev);
    h.write_u64(cost.cluster.is_uniform() as u64);
    for d in 0..n_dev {
        h.write_usize(cost.view.group_of(d));
        let hw = cost.cluster.profile_of(&cost.view, d);
        for v in [
            hw.bf16_tflops,
            hw.matmul_efficiency,
            hw.hbm_gbps,
            hw.nvlink_gbps,
            hw.allreduce_efficiency,
            hw.collective_latency,
            hw.p2p_latency,
            hw.internode_gbps,
            hw.pcie_gbps,
            hw.mem_gib,
        ] {
            h.write_f64(v);
        }
        h.write_usize(hw.gpus_per_node);
    }
    // Per-hop P2P costs exactly as the HopTable resolves them: along the
    // chunk chain's device pairs, both directions.
    for c in 0..cost.chunk_dev.len().saturating_sub(1) {
        let (a, b) = (cost.chunk_dev[c], cost.chunk_dev[c + 1]);
        h.write_f64(cost.cluster.p2p_secs(&cost.view, &cost.topo, a, b, cost.p2p_bytes));
        h.write_f64(cost.cluster.p2p_secs(&cost.view, &cost.topo, b, a, cost.p2p_bytes));
    }
    // MFU aggregation: (ranks, peak FLOPs) per group in group-index
    // order — the exact fp summation order of `aggregate_peak_flops`.
    let ranks = cost.view.ranks_per_group(cost.cluster.groups.len());
    for (g, &n) in ranks.iter().enumerate() {
        h.write_usize(n);
        h.write_f64(cost.cluster.groups[g].hw.bf16_tflops);
    }
    h.finish()
}

/// Query-context fingerprint: the evaluation inputs that live outside
/// the cost model (model identity for the DP gradient volume, caps,
/// simulation mode).
fn ctx_fingerprint(ctx: &EvalContext) -> u64 {
    let mut h = Fnv64::new();
    h.write_str(ctx.model.name());
    h.write_usize(ctx.model.total_params());
    h.write_usize(ctx.mem_cap_bytes);
    h.write_usize(ctx.seq);
    h.write_usize(ctx.vit_tokens);
    h.write_usize(ctx.mb_size);
    h.write_str(ctx.sim.label());
    h.finish()
}

/// Identity of one memoized evaluation: the resolved-content and context
/// fingerprints plus the exact candidate coordinates that pick the
/// schedule. Candidate `id` is deliberately absent — ids are
/// per-enumeration labels, not physics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct EvalKey {
    cost_fp: u64,
    ctx_fp: u64,
    tp: usize,
    pp: usize,
    dp: usize,
    vpp: usize,
    kind: u8,
    order: u8,
    n_mb: usize,
    offload_warmup: u32,
    offload_steady: u32,
    reload_lead: usize,
    ac: u8,
    /// Fingerprint of the explicit stage→group map (0 = unmapped).
    map_fp: u64,
}

/// Content fingerprint of a [`StageMap`] (0 is reserved for "no map":
/// the hash seeds non-zero and every real map writes bytes).
fn map_fingerprint(map: Option<&StageMap>) -> u64 {
    let Some(map) = map else { return 0 };
    let mut h = Fnv64::new();
    h.write_usize(map.rows.len());
    for (row, &w) in map.rows.iter().zip(&map.dp_widths) {
        h.write_usize(w);
        h.write_usize(row.len());
        for &g in row {
            h.write_usize(g);
        }
    }
    h.finish()
}

impl EvalKey {
    pub fn new(cost_fp: u64, ctx: &EvalContext, c: &Candidate) -> EvalKey {
        EvalKey {
            cost_fp,
            ctx_fp: ctx_fingerprint(ctx),
            tp: c.tp,
            pp: c.pp,
            dp: c.dp,
            vpp: c.vpp(),
            kind: c.kind as u8,
            order: c.order as u8,
            n_mb: c.n_mb,
            offload_warmup: c.offload.alpha_warmup.to_bits(),
            offload_steady: c.offload.alpha_steady.to_bits(),
            reload_lead: c.offload.reload_lead,
            ac: c.ac as u8,
            map_fp: map_fingerprint(c.map.as_deref()),
        }
    }
}

/// Per-search cost-model memo: the cost models of one *shape* —
/// (tp, pp, dp, vpp, order, placement, ac) plus the optional stage→group
/// map — shared by the pre-filter pass and the parallel simulation
/// workers via `Arc`. Unmapped shapes hold one model; mapped shapes hold
/// one per replica class (each with its own view and DP width).
#[derive(Clone)]
pub struct CostEntry {
    pub models: Vec<Arc<CostModel>>,
    /// Combined resolved-content fingerprint (for unmapped shapes,
    /// exactly [`cost_fingerprint`] of the single model).
    pub fp: u64,
}

type CostShapeKey = ((usize, usize, usize, usize, u8, u8, u8), Option<Arc<StageMap>>);

#[derive(Default)]
pub struct CostMemo {
    map: BTreeMap<CostShapeKey, CostEntry>,
}

impl CostMemo {
    pub fn new() -> CostMemo {
        CostMemo::default()
    }

    fn key(c: &Candidate) -> CostShapeKey {
        (
            (c.tp, c.pp, c.dp, c.vpp(), c.order as u8, c.placement() as u8, c.ac as u8),
            c.map.clone(),
        )
    }

    /// The memoized primary model (class 0 for mapped shapes) and the
    /// shape fingerprint.
    pub fn get(&self, c: &Candidate) -> Option<(Arc<CostModel>, u64)> {
        self.map.get(&Self::key(c)).map(|e| (e.models[0].clone(), e.fp))
    }

    /// Every per-class model of a mapped shape (`None` when the shape was
    /// never built or the candidate is unmapped with no entry).
    pub fn models_of(&self, c: &Candidate) -> Option<Vec<Arc<CostModel>>> {
        self.map.get(&Self::key(c)).map(|e| e.models.clone())
    }

    /// The memoized cost model(s) for `c`, building (and fingerprinting)
    /// them on first sight.
    pub fn get_or_build(&mut self, ctx: &EvalContext, c: &Candidate) -> (Arc<CostModel>, u64) {
        let e = self.map.entry(Self::key(c)).or_insert_with(|| {
            let models: Vec<Arc<CostModel>> = match c.map.as_deref() {
                Some(map) => (0..map.n_classes())
                    .map(|k| Arc::new(ctx.class_cost_model(c, k)))
                    .collect(),
                None => vec![Arc::new(ctx.cost_model(c))],
            };
            let fp = match c.map.as_deref() {
                None => cost_fingerprint(&models[0]),
                Some(map) => {
                    let mut h = Fnv64::new();
                    h.write_usize(models.len());
                    for m in &models {
                        h.write_u64(cost_fingerprint(m));
                    }
                    h.write_u64(map_fingerprint(Some(map)));
                    h.finish()
                }
            };
            CostEntry { models, fp }
        });
        (e.models[0].clone(), e.fp)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Cross-query evaluation memo. A hit re-labels the stored evaluation
/// with the requesting candidate (ids are per-enumeration); everything
/// else is bit-identical to a fresh simulation by the fingerprint
/// argument above, so memoized searches rank — and serialize — exactly
/// like cold ones.
#[derive(Default)]
pub struct EvalMemo {
    map: BTreeMap<EvalKey, Evaluation>,
    /// Evaluations answered from the memo (for serve diagnostics).
    pub hits: usize,
    /// Evaluations that had to be simulated.
    pub misses: usize,
}

impl EvalMemo {
    pub fn new() -> EvalMemo {
        EvalMemo::default()
    }

    pub fn lookup(&mut self, key: &EvalKey, c: &Candidate) -> Option<Evaluation> {
        match self.map.get(key) {
            Some(e) => {
                self.hits += 1;
                let mut e = e.clone();
                e.candidate = c.clone();
                Some(e)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn record(&mut self, key: EvalKey, e: Evaluation) {
        self.map.insert(key, e);
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Canonical cache key for a whole [`PlanQuery`]: model dims × cluster
/// spec (full JSON, sorted keys) × budget × caps × candidate-space knobs
/// × search mode. `threads` is deliberately excluded — results are
/// bit-identical at any thread count, so queries differing only in
/// worker count share one entry.
pub fn canonical_key(q: &PlanQuery) -> String {
    use std::fmt::Write as _;
    let mut k = String::new();
    let _ = write!(
        k,
        "model={};params={};chunks={}-{};cluster={};gpus={};mem={:016x};seq={};mb={};vit={}",
        q.model.name(),
        q.model.total_params(),
        q.model.min_chunks(),
        q.model.max_chunks(),
        q.cluster.to_json(),
        q.gpus,
        q.mem_cap_gib.to_bits(),
        q.seq,
        q.mb_size,
        q.vit_tokens,
    );
    let _ = write!(
        k,
        ";slack={:016x};keep={};search={};sim={};n_mb={:?}",
        q.prune_slack.to_bits(),
        q.min_keep,
        q.search.label(),
        q.sim.label(),
        q.n_mb_options,
    );
    for o in &q.offload_variants {
        let _ = write!(
            k,
            ";off={:08x},{:08x},{}",
            o.alpha_warmup.to_bits(),
            o.alpha_steady.to_bits(),
            o.reload_lead
        );
    }
    for kind in &q.kinds {
        let _ = write!(k, ";kind={}", kind.name());
    }
    k
}

/// One answered cache query (the serve loop's unit of work).
#[derive(Debug, Clone)]
pub struct CacheAnswer {
    /// The `PlanReport` JSON line — byte-identical to what a cold
    /// `plan(&q)` would serialize.
    pub json: String,
    /// Answered from the report store without searching?
    pub hit: bool,
    /// On a miss: simulations answered from the evaluation memo.
    pub sims_reused: usize,
    /// On a miss: simulations actually replayed.
    pub sims_run: usize,
}

/// The long-lived planning cache behind `stp serve`: a report store over
/// [`canonical_key`] plus a shared [`EvalMemo`] for incremental
/// re-search on cluster (or budget) deltas.
#[derive(Default)]
pub struct PlanCache {
    reports: BTreeMap<String, String>,
    evals: EvalMemo,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Answer a query: exact hits return the stored report; misses run a
    /// memoized search (reusing every evaluation whose resolved physics
    /// is unchanged) and store the result.
    pub fn query(&mut self, q: &PlanQuery) -> CacheAnswer {
        let key = canonical_key(q);
        if let Some(json) = self.reports.get(&key) {
            return CacheAnswer { json: json.clone(), hit: true, sims_reused: 0, sims_run: 0 };
        }
        let (h0, m0) = (self.evals.hits, self.evals.misses);
        let report = plan_with_memo(q, Some(&mut self.evals));
        let json = report.to_json().to_string();
        self.reports.insert(key, json.clone());
        CacheAnswer {
            json,
            hit: false,
            sims_reused: self.evals.hits - h0,
            sims_run: self.evals.misses - m0,
        }
    }

    /// Stored reports (exact-key entries).
    pub fn len(&self) -> usize {
        self.reports.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reports.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{GroupOrder, HardwareProfile};
    use crate::model::ModelConfig;
    use crate::plan::space::PlanModel;
    use crate::schedule::{OffloadParams, ScheduleKind};
    use crate::sim::SimMode;

    fn ctx(cluster: ClusterSpec) -> EvalContext {
        EvalContext {
            model: PlanModel::Llm(ModelConfig::qwen2_12b()),
            cluster,
            mem_cap_bytes: (80.0 * (1u64 << 30) as f64) as usize,
            seq: 2048,
            vit_tokens: 0,
            mb_size: 1,
            sim: SimMode::Folded,
        }
    }

    fn cand(tp: usize, pp: usize, dp: usize) -> Candidate {
        Candidate {
            id: 7,
            tp,
            pp,
            dp,
            kind: ScheduleKind::Stp,
            n_mb: 16,
            order: GroupOrder::Declared,
            offload: OffloadParams::default(),
            offload_variant: 0,
            ac: crate::sim::AcMode::None,
            map: None,
            vpp_gene: 0,
        }
    }

    #[test]
    fn fingerprints_are_stable_and_content_sensitive() {
        let a = ctx(ClusterSpec::uniform(HardwareProfile::a800()));
        let c = cand(2, 2, 2);
        let fp1 = cost_fingerprint(&a.cost_model(&c));
        let fp2 = cost_fingerprint(&a.cost_model(&c));
        assert_eq!(fp1, fp2, "same content must fingerprint identically");
        let h = ctx(ClusterSpec::uniform(HardwareProfile::h20()));
        assert_ne!(
            fp1,
            cost_fingerprint(&h.cost_model(&c)),
            "different hardware must change the fingerprint"
        );
        assert_ne!(
            fp1,
            cost_fingerprint(&a.cost_model(&cand(4, 2, 1))),
            "different topology must change the fingerprint"
        );
    }

    #[test]
    fn cost_memo_shares_one_model_per_shape() {
        let ctx = ctx(ClusterSpec::uniform(HardwareProfile::a800()));
        let mut memo = CostMemo::new();
        assert!(memo.is_empty());
        let (m1, fp1) = memo.get_or_build(&ctx, &cand(2, 2, 2));
        // Same shape, different kind/n_mb: the cost model is reused.
        let mut c2 = cand(2, 2, 2);
        c2.kind = ScheduleKind::ZbV;
        c2.n_mb = 32;
        let (m2, fp2) = memo.get_or_build(&ctx, &c2);
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(fp1, fp2);
        assert_eq!(memo.len(), 1);
        memo.get_or_build(&ctx, &cand(4, 2, 1));
        assert_eq!(memo.len(), 2);
    }

    #[test]
    fn eval_memo_relabels_hits_with_the_requesting_candidate() {
        let ctx = ctx(ClusterSpec::uniform(HardwareProfile::a800()));
        let c = cand(2, 2, 2);
        let mut costs = CostMemo::new();
        let (_, fp) = costs.get_or_build(&ctx, &c);
        let key = EvalKey::new(fp, &ctx, &c);
        let mut memo = EvalMemo::new();
        assert!(memo.lookup(&key, &c).is_none());
        assert_eq!(memo.misses, 1);
        let e = crate::plan::evaluate::evaluate(&ctx, &c);
        memo.record(key, e.clone());
        let mut relabeled = c.clone();
        relabeled.id = 99;
        let hit = memo.lookup(&key, &relabeled).expect("recorded key must hit");
        assert_eq!(memo.hits, 1);
        assert_eq!(hit.candidate.id, 99);
        assert_eq!(hit.throughput.to_bits(), e.throughput.to_bits());
    }

    #[test]
    fn canonical_key_ignores_threads_but_not_budget() {
        let model = PlanModel::Llm(ModelConfig::qwen2_12b());
        let cluster = ClusterSpec::uniform(HardwareProfile::a800());
        let q = PlanQuery::new(model.clone(), cluster.clone(), 8);
        let mut same = q.clone();
        same.threads = 7;
        assert_eq!(canonical_key(&q), canonical_key(&same));
        let mut bigger = q.clone();
        bigger.gpus = 16;
        assert_ne!(canonical_key(&q), canonical_key(&bigger));
        let mut unfolded = q.clone();
        unfolded.sim = SimMode::Unfolded;
        assert_ne!(canonical_key(&q), canonical_key(&unfolded));
    }
}
