//! Parallelism auto-planner: simulator-backed search over
//! (TP, PP, DP) × schedule kind × microbatch count × offload parameters.
//!
//! The paper fixes one parallel configuration per experiment (TP=8/PP=2
//! for the 12.1B LLM) and picks the STP variant by hand. This subsystem
//! closes the loop for arbitrary model + GPU budgets (DESIGN.md §7):
//! given a [`PlanQuery`] it
//!
//! 1. **enumerates** every (TP, PP, DP) factorization of the budget ×
//!    every [`ScheduleKind`](crate::schedule::ScheduleKind) × a
//!    microbatch sweep × offload variants ([`space`]), with MLLM
//!    chunk-imbalance handled through the scaled schedule builders;
//! 2. **prunes** with shape rules, the Table-1 closed-form memory peak
//!    (memory feasibility is a first-class constraint, not an
//!    afterthought), and a theory-estimate throughput bound
//!    ([`constraints`], [`evaluate`]);
//! 3. **simulates** under the event-driven no-trace replay on a thread
//!    pool with per-worker scratch arenas ([`search`]) —
//!    deterministically, regardless of thread count — either every
//!    theory-bound survivor ([`SearchMode::Exhaustive`]), a
//!    theory-seeded beam walk over (tp, pp, n_mb, order) neighbors
//!    ([`SearchMode::Beam`], for budgets of hundreds of GPUs where
//!    exhaustive simulation stops scaling), or an evolutionary search
//!    ([`SearchMode::Evo`], [`evo`]) whose genome additionally spans
//!    activation checkpointing, virtual-pipeline overrides and explicit
//!    stage→group maps with per-class DP widths on mixed pools
//!    (DESIGN.md §16);
//! 4. **reports** a ranked [`PlanReport`] with throughput, MFU, TP/PP
//!    bubble decomposition and peak memory per candidate, serializable
//!    to JSON and traceable via `trace::write_chrome_trace` ([`report`]).
//!
//! Entry points: [`plan`] for one-shot queries (the `stp plan`
//! subcommand and `examples/auto_plan.rs`), [`PlanCache`] for query
//! streams (`stp serve`) — a keyed report cache over [`canonical_key`]
//! plus a cross-query [`EvalMemo`] so cluster-delta re-searches only
//! simulate candidates whose resolved hardware actually changed
//! ([`cache`], DESIGN.md §15) — and [`evaluate::evaluate`] /
//! [`evaluate::simulate_candidate`] for inspecting individual candidates.

pub mod artifact;
pub mod cache;
pub mod constraints;
pub mod evaluate;
pub mod evo;
pub mod report;
pub mod search;
pub mod space;

pub use artifact::{PlanArtifact, PLAN_SCHEMA};
pub use cache::{canonical_key, cost_fingerprint, CacheAnswer, CostMemo, EvalKey, EvalMemo};
pub use cache::PlanCache;
pub use constraints::Reject;
pub use evaluate::{evaluate, evaluate_in_memo, simulate_candidate, EvalContext, Evaluation};
pub use report::PlanReport;
pub use search::{evaluate_parallel, evaluate_parallel_memo, plan, plan_with_memo};
pub use search::{PlanQuery, SearchMode};
pub use space::{Candidate, PlanModel, StageMap};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, HardwareProfile};
    use crate::model::ModelConfig;

    #[test]
    fn end_to_end_plan_ranks_stp_over_baselines_at_paper_topology() {
        // Within the paper's own topology (tp8-pp2-dp1, m=64) the ranked
        // report must reproduce the headline: STP above 1F1B-I and ZB-V
        // (braided blocks hide the TP communication the baselines expose).
        use crate::schedule::ScheduleKind;

        let mut q = PlanQuery::new(
            PlanModel::Llm(ModelConfig::qwen2_12b()),
            ClusterSpec::uniform(HardwareProfile::a800()),
            16,
        );
        q.seq = 3072;
        q.n_mb_options = vec![64];
        q.threads = 2;
        let r = plan(&q);
        assert!(r.best().is_some(), "16 GPUs must fit the 12B model");
        let thr_of = |kind: ScheduleKind| {
            r.ranked
                .iter()
                .find(|e| {
                    let c = &e.candidate;
                    c.tp == 8 && c.pp == 2 && c.dp == 1 && c.kind == kind && c.n_mb == 64
                })
                .map(|e| e.throughput)
                .expect("paper-topology candidate was simulated")
        };
        let ours = thr_of(ScheduleKind::Stp);
        assert!(ours > thr_of(ScheduleKind::OneF1BInterleaved));
        assert!(ours > thr_of(ScheduleKind::ZbV));
    }
}
