//! Bench: parallel candidate evaluation of the auto-planner (the L3
//! §Perf claim that thousands of simulated candidates rank in seconds,
//! and that evaluation scales with worker threads).
//!
//! `cargo bench --bench plan_search`

use std::time::Instant;

use stp::cluster::HardwareProfile;
use stp::model::ModelConfig;
use stp::plan::{evaluate_parallel, plan, PlanModel, PlanQuery};
use stp::plan::constraints::{admissible, memory_feasible};
use stp::plan::space::enumerate;

fn main() {
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        HardwareProfile::a800(),
        16,
    );
    q.seq = 3072;
    let ctx = q.eval_context();

    // Fixed survivor set (same filters the search applies) so every
    // thread count does identical work.
    let survivors: Vec<_> = enumerate(q.gpus, &q.kinds, &q.n_mb_options, &q.offload_variants)
        .into_iter()
        .filter(|c| admissible(&q.model, c).is_ok())
        .filter(|c| {
            let cost = ctx.cost_model(c);
            memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes)
        })
        .collect();
    println!("evaluating {} candidates (16-GPU budget, 12.1B, A800, seq 3072)\n", survivors.len());

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize, 2, 4];
    if cores > 4 {
        thread_counts.push(cores);
    }
    thread_counts.dedup();

    println!("{:>8} {:>10} {:>12} {:>9}", "threads", "secs", "cands/s", "speedup");
    let mut t1 = None;
    for &threads in &thread_counts {
        if threads > cores {
            continue;
        }
        // Warm once, then take the median of 3.
        let _ = evaluate_parallel(&ctx, &survivors, threads);
        let mut times = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            let evals = evaluate_parallel(&ctx, &survivors, threads);
            times.push(t0.elapsed().as_secs_f64());
            assert_eq!(evals.len(), survivors.len());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let secs = times[1];
        let base = *t1.get_or_insert(secs);
        println!(
            "{threads:>8} {secs:>10.3} {:>12.0} {:>8.2}x",
            survivors.len() as f64 / secs,
            base / secs
        );
    }

    // End-to-end: the whole plan() pipeline at full parallelism.
    let t0 = Instant::now();
    let report = plan(&q);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\nfull plan(): {} enumerated -> {} simulated in {:.2}s; best = {}",
        report.n_enumerated,
        report.n_simulated(),
        secs,
        report
            .best()
            .map(|b| b.candidate.label())
            .unwrap_or_else(|| "none".into())
    );
}
