//! Bench: parallel candidate evaluation of the auto-planner (the L3
//! §Perf claim that thousands of simulated candidates rank in seconds,
//! and that evaluation scales with worker threads), plus a perf baseline
//! for the heterogeneous (`--cluster`) search path whose ranked report is
//! emitted as JSON next to the bench output.
//!
//! `cargo bench --bench plan_search`

use std::time::Instant;

use stp::cluster::{ClusterSpec, HardwareProfile};
use stp::model::ModelConfig;
use stp::plan::{evaluate_parallel, plan, PlanModel, PlanQuery};
use stp::plan::constraints::{admissible, memory_feasible};
use stp::plan::space::enumerate;

fn main() {
    let mut q = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::uniform(HardwareProfile::a800()),
        16,
    );
    q.seq = 3072;
    let ctx = q.eval_context();

    // Fixed survivor set (same filters the search applies) so every
    // thread count does identical work.
    let orders = q.cluster.group_orders();
    let survivors: Vec<_> =
        enumerate(q.gpus, &q.kinds, &q.n_mb_options, &orders, &q.offload_variants)
            .into_iter()
            .filter(|c| admissible(&q.model, &q.cluster, c).is_ok())
            .filter(|c| {
                let cost = ctx.cost_model(c);
                memory_feasible(&cost, c.kind, c.n_mb, ctx.mem_cap_bytes)
            })
            .collect();
    println!("evaluating {} candidates (16-GPU budget, 12.1B, A800, seq 3072)\n", survivors.len());

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize, 2, 4];
    if cores > 4 {
        thread_counts.push(cores);
    }
    thread_counts.dedup();

    println!("{:>8} {:>10} {:>12} {:>9}", "threads", "secs", "cands/s", "speedup");
    let mut t1 = None;
    for &threads in &thread_counts {
        if threads > cores {
            continue;
        }
        // Warm once, then take the median of 3.
        let _ = evaluate_parallel(&ctx, &survivors, threads);
        let mut times = Vec::new();
        for _ in 0..3 {
            let t0 = Instant::now();
            let evals = evaluate_parallel(&ctx, &survivors, threads);
            times.push(t0.elapsed().as_secs_f64());
            assert_eq!(evals.len(), survivors.len());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let secs = times[1];
        let base = *t1.get_or_insert(secs);
        println!(
            "{threads:>8} {secs:>10.3} {:>12.0} {:>8.2}x",
            survivors.len() as f64 / secs,
            base / secs
        );
    }

    // End-to-end: the whole plan() pipeline at full parallelism.
    let t0 = Instant::now();
    let report = plan(&q);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\nfull plan(): {} enumerated -> {} simulated in {:.2}s; best = {}",
        report.n_enumerated,
        report.n_simulated(),
        secs,
        report
            .best()
            .map(|b| b.candidate.label())
            .unwrap_or_else(|| "none".into())
    );

    // Heterogeneous search path (`stp plan --cluster mixed`): same budget
    // over the mixed A800+H20 preset — the perf baseline for group-order
    // enumeration, stage-time-balanced partitioning and per-device OOM.
    let mut hq = PlanQuery::new(
        PlanModel::Llm(ModelConfig::qwen2_12b()),
        ClusterSpec::mixed_a800_h20(),
        16,
    );
    hq.seq = 3072;
    let t0 = Instant::now();
    let hetero = plan(&hq);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "\nhetero plan() [{}]: {} enumerated -> {} simulated in {:.2}s ({:.0} cands/s); best = {}",
        hetero.cluster_name,
        hetero.n_enumerated,
        hetero.n_simulated(),
        secs,
        hetero.n_simulated() as f64 / secs.max(1e-9),
        hetero
            .best()
            .map(|b| b.candidate.label())
            .unwrap_or_else(|| "none".into())
    );
    let json_path = if std::path::Path::new("target").is_dir() {
        std::path::PathBuf::from("target/plan-search-hetero.json")
    } else {
        std::env::temp_dir().join("plan-search-hetero.json")
    };
    match std::fs::write(&json_path, hetero.to_json().to_string()) {
        Ok(()) => println!("hetero ranked report: {}", json_path.display()),
        Err(e) => eprintln!("hetero report write failed: {e}"),
    }
}
