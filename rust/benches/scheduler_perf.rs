//! Bench: schedule-construction throughput (the L3 §Perf target —
//! generation must be O(p·m)-ish and interactive at every paper scale).
//!
//! `cargo bench --bench scheduler_perf`

use std::time::Instant;

use stp::cluster::Topology;
use stp::schedule::{build_schedule, ScheduleKind};

fn main() {
    println!("{:12} {:>4} {:>5} {:>8} {:>12} {:>12}", "schedule", "pp", "m", "ops", "build ms", "ops/ms");
    for kind in ScheduleKind::all() {
        for (pp, m) in [(2usize, 64usize), (4, 128), (8, 192), (8, 512)] {
            let topo = Topology::new(4, pp, 1);
            // Warm once, then time the median of 5.
            let _ = build_schedule(kind, &topo, m);
            let mut times = Vec::new();
            let mut ops = 0;
            for _ in 0..5 {
                let t0 = Instant::now();
                let s = build_schedule(kind, &topo, m);
                times.push(t0.elapsed().as_secs_f64());
                ops = s.num_ops();
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ms = times[2] * 1e3;
            println!(
                "{:12} {:>4} {:>5} {:>8} {:>12.3} {:>12.0}",
                kind.name(),
                pp,
                m,
                ops,
                ms,
                ops as f64 / ms
            );
        }
    }
}
