//! Bench: regenerate the LLM evaluation — Fig. 7 (12.1B/16 GPU), Fig. 8
//! (26.3B/32 GPU), Fig. 9 (peak activation memory), Table 1 (theory vs
//! simulation) and the appendix Tables 5/6/7 grids.
//!
//! `cargo bench --bench llm_throughput`

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("{}", stp::bench::table1());
    println!("{}", stp::bench::fig7());
    println!("{}", stp::bench::fig8());
    println!("{}", stp::bench::fig9());
    println!("{}", stp::bench::table567());
    println!("{}", stp::bench::table4());
    println!("{}", stp::bench::table8());
    println!("{}", stp::bench::fig13());
    println!("{}", stp::bench::table9());
    println!("{}", stp::bench::table10());
    println!("[llm_throughput completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
