//! Bench: regenerate paper Fig. 1 (TP communication share and braided
//! overlap speedup vs TP size) and time the block machinery.
//!
//! `cargo bench --bench fig1_tp_overlap`

use std::time::Instant;

fn main() {
    println!("{}", stp::bench::fig1());

    // Micro-timing of the two-stream block machine itself (the simulator
    // hot path): time_braided on a 10-layer chunk.
    use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
    use stp::model::ModelConfig;
    use stp::sim::CostModel;
    let cost = CostModel::analytic(
        &ModelConfig::qwen2_12b(),
        &Topology::new(8, 2, 1),
        &ClusterSpec::uniform(HardwareProfile::a800()),
        6144,
        1,
    );
    let c = &cost.chunks[0];
    let iters = 20_000;
    let t0 = Instant::now();
    let mut acc = 0.0;
    for _ in 0..iters {
        acc += c.time_braided(c, true).duration;
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("block-machine: time_braided x{iters} -> {:.2} us/call (acc {acc:.1})", per * 1e6);
}
