//! Bench: regenerate the MLLM evaluation — Table 3 (Qwen2-VL throughput +
//! peak memory across balanced/unbalanced splits) and Fig. 10 (offload
//! variant).
//!
//! `cargo bench --bench mllm_throughput`

use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("{}", stp::bench::table3());
    println!("{}", stp::bench::fig10());
    println!("[mllm_throughput completed in {:.1}s]", t0.elapsed().as_secs_f64());
}
