//! Bench: simulator replay throughput (L3 §Perf target: ≥ 10^5 ops/s so
//! the full table sweeps stay interactive).
//!
//! `cargo bench --bench sim_perf`

use std::time::Instant;

use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
use stp::model::ModelConfig;
use stp::schedule::{build_schedule, ScheduleKind};
use stp::sim::{CostModel, Simulator};

fn main() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    println!("{:12} {:>4} {:>5} {:>8} {:>10} {:>12}", "schedule", "pp", "m", "ops", "sim ms", "ops/ms");
    for kind in [ScheduleKind::OneF1BInterleaved, ScheduleKind::ZbV, ScheduleKind::Stp] {
        for (pp, m) in [(2usize, 64usize), (4, 192), (8, 512)] {
            let topo = Topology::new(4, pp, 1);
            let cost = CostModel::analytic(&model, &topo, &cluster, 4096, 1);
            let s = build_schedule(kind, &topo, m);
            let _ = Simulator::new(&cost).run(&s); // warm
            let mut times = Vec::new();
            for _ in 0..5 {
                let t0 = Instant::now();
                let _ = Simulator::new(&cost).run(&s);
                times.push(t0.elapsed().as_secs_f64());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let ms = times[2] * 1e3;
            println!(
                "{:12} {:>4} {:>5} {:>8} {:>10.3} {:>12.0}",
                kind.name(),
                pp,
                m,
                s.num_ops(),
                ms,
                s.num_ops() as f64 / ms
            );
        }
    }
}
