//! Bench: simulator replay throughput (L3 §Perf target: ≥ 10^5 ops/s so
//! the full table sweeps stay interactive), comparing the polling oracle
//! (`sim::reference`) against the event-driven core (`sim::Simulator`,
//! no-trace + reused arena — the planner's configuration).
//!
//! `cargo bench --bench sim_perf`

use std::time::Instant;

use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
use stp::model::ModelConfig;
use stp::schedule::{build_schedule, Schedule, ScheduleKind};
use stp::sim::{reference, CostModel, SimArena, Simulator};

fn median_ms(mut times: Vec<f64>) -> f64 {
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2] * 1e3
}

fn time_reference(cost: &CostModel, s: &Schedule) -> f64 {
    let _ = reference::Simulator::new(cost).run(s); // warm
    let mut times = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        let _ = reference::Simulator::new(cost).run(s);
        times.push(t0.elapsed().as_secs_f64());
    }
    median_ms(times)
}

fn time_event(cost: &CostModel, s: &Schedule, arena: &mut SimArena) -> f64 {
    let _ = Simulator::new(cost).without_trace().try_run_in(s, arena).unwrap(); // warm
    let mut times = Vec::new();
    for _ in 0..5 {
        let t0 = Instant::now();
        let _ = Simulator::new(cost).without_trace().try_run_in(s, arena).unwrap();
        times.push(t0.elapsed().as_secs_f64());
    }
    median_ms(times)
}

fn main() {
    let model = ModelConfig::qwen2_12b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let mut arena = SimArena::default();
    println!(
        "{:12} {:>4} {:>5} {:>8} {:>10} {:>12} {:>10} {:>12} {:>9}",
        "schedule", "pp", "m", "ops", "ref ms", "ref ops/ms", "event ms", "event ops/ms", "speedup"
    );
    for kind in [ScheduleKind::OneF1BInterleaved, ScheduleKind::ZbV, ScheduleKind::Stp] {
        for (pp, m) in [(2usize, 64usize), (4, 192), (8, 512)] {
            let topo = Topology::new(4, pp, 1);
            let cost = CostModel::analytic(&model, &topo, &cluster, 4096, 1);
            let s = build_schedule(kind, &topo, m);
            let ref_ms = time_reference(&cost, &s);
            let ev_ms = time_event(&cost, &s, &mut arena);
            let ops = s.num_ops() as f64;
            println!(
                "{:12} {:>4} {:>5} {:>8} {:>10.3} {:>12.0} {:>10.3} {:>12.0} {:>8.1}x",
                kind.name(),
                pp,
                m,
                s.num_ops(),
                ref_ms,
                ops / ref_ms,
                ev_ms,
                ops / ev_ms,
                ref_ms / ev_ms
            );
        }
    }
}
