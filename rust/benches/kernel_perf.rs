//! Bench: naive vs cache-blocked vs SIMD GEMM microkernels in isolation,
//! on the shapes the nine AOT units actually hit (DESIGN.md §11, §13) —
//! so kernel regressions are visible without running the whole executor.
//!
//! Shapes are taken from the python `test` preset
//! (rows = mb·seq = 32, d = 64, per-rank ffn = 48, vocab = 256) and the
//! `--virtual-scale auto` proxy on a big host (rows = 32, d = 128,
//! ffn = 256, vocab = 256), for each of the three layouts: `A·B`
//! (forwards/projections), `Aᵀ·B` (weight grads), `A·Bᵀ` (input grads).
//! All three paths are bit-equal (asserted here per shape — the SIMD
//! tile keeps one accumulator per output element in depth order), so the
//! comparison is purely speed. The SIMD leg runs with a 4-wide worker
//! pool; only the `big *` shapes clear the parallel-engagement floor.
//!
//! GFLOP/s per (shape, path) also land in `BENCH_kernel_perf.json` at
//! the repo root (a CI perf-smoke artifact).
//!
//! `cargo bench --bench kernel_perf`

use std::collections::BTreeMap;
use std::time::Instant;

use stp::config::Json;
use stp::exec::kernels::{gemm, reference, KernelCtx};
use stp::exec::Rng;

fn randn(seed: u64, n: usize) -> Vec<f32> {
    Rng::for_purpose(7, seed, 3, 0).normal_vec(n, 1.0)
}

fn median_secs(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Time `f` (median of `reps` runs after one warm-up).
fn time(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    median_secs(times)
}

fn run_gemm(cx: &mut KernelCtx, lay: &str, a: &[f32], b: &[f32], n: usize, k: usize, m: usize, out: &mut [f32]) {
    out.iter_mut().for_each(|v| *v = 0.0);
    match lay {
        "ab" => gemm::matmul(cx, a, b, n, k, m, out),
        "atb" => gemm::matmul_at(cx, a, b, k, n, m, out),
        _ => gemm::matmul_bt(cx, a, b, n, k, m, out),
    }
}

fn main() {
    // (label, layout, n, k, m): the unit GEMMs at `test`-preset dims and
    // at the auto-scaled proxy. rows = mb·seq; qkv/ffn/head projections.
    let cases: &[(&str, &str, usize, usize, usize)] = &[
        ("qkv proj (test)", "ab", 32, 64, 64),
        ("ffn up (test)", "ab", 32, 64, 48),
        ("ffn down (test)", "ab", 32, 48, 64),
        ("head logits (test)", "ab", 32, 64, 256),
        ("head dx (test)", "abt", 32, 256, 64),
        ("head dw (test)", "atb", 32, 64, 256),
        ("ffn dw (test)", "atb", 32, 64, 48),
        ("ffn dx (test)", "abt", 32, 48, 64),
        ("ffn up (scaled)", "ab", 32, 128, 256),
        ("ffn down (scaled)", "ab", 32, 256, 128),
        ("head logits (scaled)", "ab", 32, 128, 256),
        ("head dx (scaled)", "abt", 32, 256, 128),
        ("head dw (scaled)", "atb", 32, 128, 256),
        ("big square", "ab", 256, 256, 256),
        ("big dx", "abt", 256, 1024, 256),
        ("big dw", "atb", 256, 256, 1024),
    ];

    let mut blocked_cx = KernelCtx::serial(false);
    let mut simd_cx = KernelCtx::with_workers(true, 4);
    // Checksum defeats dead-code elimination without `black_box` (which
    // would raise the crate's MSRV).
    let mut sink = 0.0f64;
    let mut entries: Vec<Json> = Vec::new();
    println!(
        "{:22} {:>4} {:>14} {:>11} {:>11} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "gemm",
        "lay",
        "n x k x m",
        "naive µs",
        "blocked µs",
        "simd µs",
        "naive GF",
        "blkd GF",
        "simd GF",
        "blk spd",
        "simd spd"
    );
    for &(label, lay, n, k, m) in cases {
        let reps = (1 << 22) / (n * k * m).max(1) + 3;
        let (a, b) = match lay {
            "ab" => (randn(1, n * k), randn(2, k * m)),
            "atb" => (randn(3, k * n), randn(4, k * m)),
            _ => (randn(5, n * k), randn(6, m * k)),
        };
        let mut out = vec![0.0f32; n * m];

        let naive_s = time(reps, || {
            let got = match lay {
                "ab" => reference::matmul(&a, &b, n, k, m),
                "atb" => reference::matmul_at(&a, &b, k, n, m),
                _ => reference::matmul_bt(&a, &b, n, k, m),
            };
            sink += got[0] as f64;
        });
        let blocked_s = time(reps, || {
            run_gemm(&mut blocked_cx, lay, &a, &b, n, k, m, &mut out);
            sink += out[0] as f64;
        });
        let simd_s = time(reps, || {
            run_gemm(&mut simd_cx, lay, &a, &b, n, k, m, &mut out);
            sink += out[0] as f64;
        });

        // Bit-parity sanity on the benched shape — both fast paths.
        let want = match lay {
            "ab" => reference::matmul(&a, &b, n, k, m),
            "atb" => reference::matmul_at(&a, &b, k, n, m),
            _ => reference::matmul_bt(&a, &b, n, k, m),
        };
        run_gemm(&mut blocked_cx, lay, &a, &b, n, k, m, &mut out);
        assert!(
            want.iter().zip(&out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{label}: blocked result diverged from naive"
        );
        run_gemm(&mut simd_cx, lay, &a, &b, n, k, m, &mut out);
        assert!(
            want.iter().zip(&out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{label}: simd result diverged from naive"
        );

        let flops = 2.0 * (n * k * m) as f64;
        println!(
            "{:22} {:>4} {:>4}x{:>4}x{:>4} {:>11.1} {:>11.1} {:>11.1} {:>9.2} {:>9.2} {:>9.2} {:>7.2}x {:>7.2}x",
            label,
            lay,
            n,
            k,
            m,
            naive_s * 1e6,
            blocked_s * 1e6,
            simd_s * 1e6,
            flops / naive_s / 1e9,
            flops / blocked_s / 1e9,
            flops / simd_s / 1e9,
            naive_s / blocked_s,
            naive_s / simd_s
        );
        let mut o = BTreeMap::new();
        o.insert("label".to_string(), Json::Str(label.into()));
        o.insert("layout".to_string(), Json::Str(lay.into()));
        o.insert("n".to_string(), Json::Num(n as f64));
        o.insert("k".to_string(), Json::Num(k as f64));
        o.insert("m".to_string(), Json::Num(m as f64));
        o.insert("naive_gflops".to_string(), Json::Num(flops / naive_s / 1e9));
        o.insert("blocked_gflops".to_string(), Json::Num(flops / blocked_s / 1e9));
        o.insert("simd_gflops".to_string(), Json::Num(flops / simd_s / 1e9));
        o.insert("blocked_speedup".to_string(), Json::Num(naive_s / blocked_s));
        o.insert("simd_speedup".to_string(), Json::Num(naive_s / simd_s));
        entries.push(Json::Obj(o));
    }
    eprintln!("(checksum {sink:.3})");

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("kernel_perf".into()));
    root.insert("simd_workers".to_string(), Json::Num(4.0));
    root.insert("entries".to_string(), Json::Arr(entries));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|r| r.join("BENCH_kernel_perf.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_kernel_perf.json"));
    match std::fs::write(&path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => println!("could not write {}: {e}", path.display()),
    }
}
