//! Bench: the MEASURED Table 11 — GEMM + All-Reduce, sequential vs
//! overlapped, on real PJRT CPU compute and the real in-process
//! all-reduce. (The two-stream-model counterpart is `stp bench table11`.)
//!
//! Scenario 1: GEMM dominates (communication fully hidden).
//! Scenario 2: All-Reduce dominates (tail exposed, GEMM unaffected).
//!
//! `cargo bench --bench table11_overlap` (requires `make artifacts`).

use std::sync::Arc;
use std::time::Instant;

use stp::comm::TpGroup;
use stp::config::Manifest;
use stp::runtime::{Runtime, Tensor};

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    let dir = std::path::Path::new("artifacts/test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(dir).unwrap();
    let d = manifest.dims.clone();
    let mut rt = Runtime::load(&manifest, &["mlp_fwd"]).unwrap();

    // The "GEMM": one MLP unit forward (three matmuls).
    let x = Tensor::f32(vec![0.1; d.mb * d.seq * d.d], &[d.mb, d.seq, d.d]);
    let g2 = Tensor::f32(vec![1.0; d.d], &[d.d]);
    let wg = Tensor::f32(vec![0.01; d.d * d.ffn_per_rank()], &[d.d, d.ffn_per_rank()]);
    let wu = wg.clone();
    let wd = Tensor::f32(vec![0.01; d.ffn_per_rank() * d.d], &[d.ffn_per_rank(), d.d]);
    let gemm_args = [&x, &g2, &wg, &wu, &wd];

    let reps = 30;
    for (label, ar_elems) in [("GEMM dominates", 1usize << 14), ("AR dominates", 1usize << 22)] {
        // Sequential: GEMM then a 2-rank all-reduce of `ar_elems` floats.
        let mut seq_times = Vec::new();
        let mut gemm_times = Vec::new();
        let mut ar_times = Vec::new();
        for _ in 0..reps {
            let group = TpGroup::new(2);
            let t0 = Instant::now();
            rt.run("mlp_fwd", &gemm_args).unwrap();
            let t_gemm = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            two_rank_allreduce(&group, ar_elems);
            let t_ar = t1.elapsed().as_secs_f64();
            seq_times.push(t_gemm + t_ar);
            gemm_times.push(t_gemm);
            ar_times.push(t_ar);
        }

        // Overlapped: the all-reduce runs on two helper threads while the
        // GEMM executes on this one (the braided-block structure).
        let mut ov_times = Vec::new();
        for _ in 0..reps {
            let group = TpGroup::new(2);
            let g2c = group.clone();
            let t0 = Instant::now();
            let h = std::thread::spawn(move || two_rank_allreduce(&g2c, ar_elems));
            rt.run("mlp_fwd", &gemm_args).unwrap();
            h.join().unwrap();
            ov_times.push(t0.elapsed().as_secs_f64());
        }

        let g = median(gemm_times) * 1e3;
        let a = median(ar_times) * 1e3;
        let s = median(seq_times) * 1e3;
        let o = median(ov_times) * 1e3;
        println!(
            "{label:16} | GEMM {g:8.3} ms | AR {a:8.3} ms | sequential {s:8.3} ms | overlapped {o:8.3} ms | saving {:5.1}%",
            100.0 * (1.0 - o / s)
        );
    }
}

/// Run a 2-rank all-reduce: both ranks on scratch threads.
fn two_rank_allreduce(group: &Arc<TpGroup>, elems: usize) {
    let g0 = group.clone();
    let g1 = group.clone();
    let h0 = std::thread::spawn(move || {
        let mut buf = vec![1.0f32; elems];
        g0.all_reduce(0, &mut buf).unwrap();
    });
    let h1 = std::thread::spawn(move || {
        let mut buf = vec![2.0f32; elems];
        g1.all_reduce(1, &mut buf).unwrap();
    });
    h0.join().unwrap();
    h1.join().unwrap();
}
