//! MLLM scenario (paper §5.3): the ViT-encoder / LM chunk imbalance that
//! motivates braiding pattern (2).
//!
//! ```text
//! cargo run --release --example mllm_pipeline
//! ```
//!
//! Simulates Qwen2-VL-14.9B with the ViT on the first virtual stage and
//! sweeps the three schedules over balanced (PP=4) and unbalanced (PP=2)
//! splits — reproducing the shape of Table 3, including the largest STP
//! win (paper: +16.7%) in the PP=2 low-ViT-intensity case.

use stp::cluster::{partition_mllm, ClusterSpec, HardwareProfile, Topology};
use stp::model::MllmConfig;
use stp::schedule::{build_schedule_scaled, ScheduleKind};
use stp::sim::{CostModel, Simulator};

fn main() {
    let mllm = MllmConfig::qwen2vl_14_9b();
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    println!(
        "model {} = {:.1}B ViT + {:.1}B LM | {}\n",
        mllm.name,
        mllm.vit.total_params() as f64 / 1e9,
        mllm.lm.total_params() as f64 / 1e9,
        cluster.name
    );

    for (tp, pp, vit_tokens, lm_seq, n_mb) in [(4, 4, 3136, 5120, 128), (8, 2, 3136, 5120, 128)] {
        let topo = Topology::new(tp, pp, 1);
        let plan = partition_mllm(&mllm, topo.chunks());
        let cost = CostModel::analytic_mllm(
            &mllm.lm, &mllm.vit, &plan, &topo, &cluster, lm_seq, vit_tokens, 1,
        );
        let scales = cost.chunk_scales();
        println!(
            "tp{tp} pp{pp} | ViT len {vit_tokens}, LM len {lm_seq} | chunk compute scales: {}",
            scales.iter().map(|s| format!("{s:.2}")).collect::<Vec<_>>().join(" ")
        );
        let mut base = None;
        for kind in ScheduleKind::paper_trio() {
            let s = build_schedule_scaled(kind, &topo, n_mb, scales.clone());
            let r = Simulator::new(&cost).run(&s);
            let thr = r.throughput();
            base.get_or_insert(thr);
            println!(
                "  {:10} {:>7.2} samples/s  peak {:>5.1} GB  ({:+.1}% vs 1f1b-i)",
                kind.name(),
                thr,
                r.peak_activation_gb(),
                100.0 * (thr / base.unwrap() - 1.0)
            );
        }
        println!();
    }
}
