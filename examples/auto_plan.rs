//! Parallelism auto-planner CLI: given a GPU budget and a per-device
//! memory cap, search every (TP, PP, DP) factorization × schedule kind ×
//! microbatch count × offload variant, simulate the survivors in
//! parallel, and print the ranked plans.
//!
//! ```text
//! cargo run --release --example auto_plan -- --gpus 16
//! cargo run --release --example auto_plan -- --gpus 32 --model 26b \
//!     --mem-gib 64 --hw h20 --topk 15 --outdir /tmp/plans --json /tmp/plan.json
//! ```
//!
//! Flags: --gpus N (default 16) | --mem-gib F (default: pool capacity) |
//! --model 12b|26b|tiny|mllm-14.9b|mllm-28.8b | --hw a800|h20 |
//! --cluster mixed|FILE.json (heterogeneous pool; overrides --hw) |
//! --seq N | --mbsize N | --threads N | --topk N | --outdir DIR |
//! --json FILE.
//!
//! The top-k plans also get Chrome traces (`stp-trace-plan<rank>-*.json`
//! under --outdir, default /tmp) for Perfetto inspection, and the ranked
//! list is compared against the fixed-configuration baseline the paper's
//! tables would suggest by hand (TP=8/PP=2, classic 1F1B).

use std::path::PathBuf;

use stp::cluster::ClusterSpec;
use stp::coordinator::{cluster_by_name, hw_by_name, parse_flags, plan_model_by_name};
use stp::plan::{evaluate, plan, simulate_candidate, Candidate, PlanQuery};
use stp::schedule::{OffloadParams, ScheduleKind};
use stp::trace::write_chrome_trace;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args);
    let get = |key: &str| flags.get(key).cloned();

    let model = plan_model_by_name(get("model").as_deref().unwrap_or("12b"));
    let cluster = match get("cluster") {
        Some(name) => match cluster_by_name(&name) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            }
        },
        None => ClusterSpec::uniform(hw_by_name(get("hw").as_deref().unwrap_or("a800"))),
    };
    let gpus: usize = get("gpus").and_then(|s| s.parse().ok()).unwrap_or(16);
    let topk: usize = get("topk").and_then(|s| s.parse().ok()).unwrap_or(3);
    let outdir = PathBuf::from(get("outdir").unwrap_or_else(|| "/tmp".into()));

    let mut q = PlanQuery::new(model, cluster, gpus);
    if let Some(v) = get("mem-gib").and_then(|s| s.parse().ok()) {
        q.mem_cap_gib = v;
    }
    if let Some(v) = get("seq").and_then(|s| s.parse().ok()) {
        q.seq = v;
    }
    if let Some(v) = get("mbsize").and_then(|s| s.parse().ok()) {
        q.mb_size = v;
    }
    if let Some(v) = get("threads").and_then(|s| s.parse().ok()) {
        q.threads = v;
    }

    let t0 = std::time::Instant::now();
    let report = plan(&q);
    let secs = t0.elapsed().as_secs_f64();
    println!("{}", report.render(topk.max(10)));
    println!(
        "search: {} schedules simulated in {:.2}s ({:.0} candidates/s)",
        report.n_simulated(),
        secs,
        report.n_simulated() as f64 / secs.max(1e-9)
    );

    // The hand-picked configuration the paper's tables would suggest for
    // this budget: the largest admissible TP ≤ 8 that divides the budget,
    // PP=2 when it fits, classic 1F1B — using *all* budgeted GPUs.
    let ctx = q.eval_context();
    let baseline_order = q.cluster.group_orders()[0];
    let mk = |tp: usize| {
        let pp = if (gpus / tp) % 2 == 0 { 2 } else { 1 };
        Candidate {
            id: usize::MAX,
            tp,
            pp,
            dp: gpus / (tp * pp),
            kind: ScheduleKind::OneF1B,
            n_mb: 64,
            order: baseline_order,
            offload: OffloadParams::default(),
            offload_variant: 0,
        }
    };
    let baseline = (1..=8.min(gpus))
        .rev()
        .filter(|tp| gpus % tp == 0)
        .map(mk)
        .find(|c| stp::plan::constraints::admissible(&q.model, &q.cluster, c).is_ok());
    match (report.best(), baseline) {
        (Some(best), Some(baseline)) => {
            let base = evaluate(&ctx, &baseline);
            println!(
                "\nfixed baseline {}{}: {:.2} samples/s -> planner {}: {:.2} samples/s ({:+.1}%)",
                baseline.label(),
                if base.feasible { "" } else { " [OOM]" },
                base.throughput,
                best.candidate.label(),
                best.throughput,
                100.0 * (best.throughput / base.throughput - 1.0)
            );
            assert!(
                !base.feasible || best.throughput >= base.throughput,
                "planner ranked below the fixed baseline"
            );
        }
        (Some(best), None) => {
            println!(
                "\nno admissible fixed baseline for this model/budget; planner best: {} \
                 ({:.2} samples/s)",
                best.candidate.label(),
                best.throughput
            );
        }
        (None, _) => println!("\nno memory-feasible plan found for this budget/cap"),
    }

    // Chrome traces for the top-k feasible plans.
    for (rank, e) in report.feasible().take(topk).enumerate() {
        let r = simulate_candidate(&ctx, &e.candidate);
        let label = format!("plan{}-{}", rank + 1, e.candidate.label().replace(' ', "-"));
        match write_chrome_trace(&outdir, &label, &r) {
            Ok(path) => println!("trace #{}: {}", rank + 1, path.display()),
            Err(err) => eprintln!("trace write failed ({}): {err}", outdir.display()),
        }
    }

    if let Some(json_path) = get("json") {
        match std::fs::write(&json_path, report.to_json().to_string()) {
            Ok(()) => println!("wrote {json_path}"),
            Err(err) => eprintln!("json write failed: {err}"),
        }
    }
}
