//! Schedule explorer: render every scheduler's timeline for the paper's
//! illustration setting (4 stages, 12 microbatches — Fig. 5 / Fig. 12) as
//! ASCII art, plus Chrome traces for Perfetto.
//!
//! ```text
//! cargo run --release --example schedule_explorer [pp] [n_mb] [outdir]
//! ```
//!
//! Traces land in `outdir` (default `/tmp`) as `stp-trace-<kind>.json`.

use std::path::PathBuf;

use stp::cluster::{HardwareProfile, Topology};
use stp::model::ModelConfig;
use stp::schedule::{assert_valid, build_schedule, ScheduleKind};
use stp::sim::{CostModel, Simulator};
use stp::trace::{ascii_timeline, write_chrome_trace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let pp: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_mb: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let outdir = PathBuf::from(args.get(2).map(String::as_str).unwrap_or("/tmp"));

    let topo = Topology::new(1, pp, 1);
    let model = ModelConfig::qwen2_12b();
    let hw = HardwareProfile::a800();
    let cost = CostModel::analytic(&model, &topo, &hw, 4096, 1);

    println!("pipeline schedules, p={pp}, m={n_mb} (paper Fig. 5 / Fig. 12 setting)\n");
    for kind in ScheduleKind::all() {
        let s = build_schedule(kind, &topo, n_mb);
        assert_valid(&s);
        let r = Simulator::new(&cost).run(&s);
        println!("{}", ascii_timeline(&r, 150));
        match write_chrome_trace(&outdir, kind.name(), &r) {
            Ok(path) => println!("  chrome trace: {}\n", path.display()),
            Err(e) => eprintln!("  trace write failed ({}): {e}\n", outdir.display()),
        }
    }
}
