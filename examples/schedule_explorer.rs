//! Schedule explorer: render every scheduler's timeline for the paper's
//! illustration setting (4 stages, 12 microbatches — Fig. 5 / Fig. 12) as
//! ASCII art, plus Chrome traces for Perfetto. Device rows in the Chrome
//! traces carry the per-device hardware-profile name, so passing a mixed
//! cluster ("mixed" or a JSON spec) yields readable heterogeneous
//! timelines.
//!
//! ```text
//! cargo run --release --example schedule_explorer [pp] [n_mb] [outdir] [cluster]
//! ```
//!
//! Traces land in `outdir` (default `/tmp`) as `stp-trace-<kind>.json`;
//! `cluster` is a pool name ("a800", "h20", "mixed") or a JSON spec path.

use std::path::PathBuf;

use stp::cluster::{GroupOrder, Topology};
use stp::coordinator::cluster_by_name;
use stp::model::ModelConfig;
use stp::schedule::{assert_valid, build_schedule, ScheduleKind};
use stp::sim::{CostModel, Simulator};
use stp::trace::{ascii_timeline, write_chrome_trace};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let pp: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let n_mb: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let outdir = PathBuf::from(args.get(2).map(String::as_str).unwrap_or("/tmp"));
    let cluster = match cluster_by_name(args.get(3).map(String::as_str).unwrap_or("a800")) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };

    let topo = Topology::new(1, pp, 1);
    let model = ModelConfig::qwen2_12b();

    println!(
        "pipeline schedules, p={pp}, m={n_mb}, cluster={} (paper Fig. 5 / Fig. 12 setting)\n",
        cluster.name
    );
    for kind in ScheduleKind::all() {
        let cost = CostModel::analytic_for(
            &model,
            &topo,
            &cluster,
            GroupOrder::Declared,
            kind.placement(),
            4096,
            1,
        );
        let s = build_schedule(kind, &topo, n_mb);
        assert_valid(&s);
        let r = Simulator::new(&cost).run(&s);
        println!("{}", ascii_timeline(&r, 150));
        match write_chrome_trace(&outdir, kind.name(), &r) {
            Ok(path) => println!("  chrome trace: {}\n", path.display()),
            Err(e) => eprintln!("  trace write failed ({}): {e}\n", outdir.display()),
        }
    }
}
