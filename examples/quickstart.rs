//! Quickstart: simulate the paper's headline configuration and print the
//! three compared schedules side by side.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Expected shape (paper Fig. 7 right): STP ("ours") beats 1F1B-I and
//! ZB-V on throughput at TP=8/PP=2 by overlapping TP All-Reduce inside
//! braided execution blocks, at the cost of a higher activation peak.

use stp::cluster::{ClusterSpec, HardwareProfile, Topology};
use stp::model::ModelConfig;
use stp::schedule::{build_schedule, ScheduleKind};
use stp::sim::{CostModel, Simulator};

fn main() {
    // Qwen2-12.1B on 16 simulated A800s: TP=8, PP=2, seq 6144.
    let model = ModelConfig::qwen2_12b();
    let topo = Topology::new(8, 2, 1);
    let cluster = ClusterSpec::uniform(HardwareProfile::a800());
    let n_mb = 64;
    let cost = CostModel::analytic(&model, &topo, &cluster, 6144, 1);

    println!(
        "model {} ({:.1}B params) | {} | {} | {n_mb} microbatches\n",
        model.name,
        model.total_params() as f64 / 1e9,
        topo,
        cluster.name
    );
    println!(
        "{:10} {:>12} {:>8} {:>12} {:>12} {:>10}",
        "schedule", "samples/s", "MFU %", "TP bub/dev", "PP bub/dev", "peak GB"
    );
    let mut base = None;
    for kind in ScheduleKind::paper_trio() {
        let schedule = build_schedule(kind, &topo, n_mb);
        let report = Simulator::new(&cost).run(&schedule);
        let thr = report.throughput();
        base.get_or_insert(thr);
        println!(
            "{:10} {:>12.2} {:>8.1} {:>11.3}s {:>11.3}s {:>10.1}",
            kind.name(),
            thr,
            100.0 * report.mfu(),
            report.tp_bubble_per_device(),
            report.pp_bubble_per_device(),
            report.peak_activation_gb(),
        );
    }
    let stp = build_schedule(ScheduleKind::Stp, &topo, n_mb);
    let r = Simulator::new(&cost).run(&stp);
    println!(
        "\nSTP gain over 1F1B-I: {:+.1}%  (paper reports up to +12.2% on real A800s)",
        100.0 * (r.throughput() / base.unwrap() - 1.0)
    );
}
