//! End-to-end validation (DESIGN.md §5, §10): train a Qwen2-style
//! transformer with REAL tensor compute through the backend-abstract
//! executor — per-(stage, tp-rank) threads, genuine TP All-Reduce and
//! pipeline P2P under the paper's STP schedule — and log the loss curve.
//!
//! ```text
//! cargo run --release --example train_e2e -- [steps] [schedule] [backend]
//! ```
//!
//! The default **virtual** backend runs in every build (miniature
//! deterministic dims, TP=2 × PP=2 × 2 virtual chunks). Passing `pjrt`
//! as the third argument executes the AOT HLO artifacts instead
//! (`make artifacts` first; needs the `pjrt` feature and real xla
//! bindings); the preset's dims then come from `artifacts/e2e`.
//!
//! Loss starts near ln(V) and must fall toward the synthetic bigram
//! corpus's entropy floor; the process exits non-zero on a flat or
//! non-finite curve (the CI train-smoke leg relies on this).

use stp::config::ManifestDims;
use stp::exec::{train, virtual_dims, BackendKind, Corpus, TrainConfig};
use stp::schedule::ScheduleKind;

fn main() -> stp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let schedule: ScheduleKind = args
        .get(1)
        .map(|s| s.parse().expect("bad schedule name"))
        .unwrap_or(ScheduleKind::Stp);
    let backend: BackendKind = args
        .get(2)
        .map(|s| s.parse().expect("bad backend name"))
        .unwrap_or(BackendKind::Virtual);

    let mut cfg = TrainConfig::virtual_default();
    cfg.backend = backend;
    cfg.schedule = schedule;
    cfg.steps = steps;
    cfg.lr = 0.03;
    cfg.verbose = true;
    let dims = match backend {
        // Pin the miniature grid explicitly instead of relying on the
        // engine's implicit default for `dims: None`.
        BackendKind::Virtual => virtual_dims(2, 2, 2, 8),
        // PJRT reads its dims from the manifest; this copy only feeds
        // the log line (the e2e preset is the test grid at vocab 8192,
        // python/compile/config.py).
        BackendKind::Pjrt => ManifestDims { vocab: 8192, ..ManifestDims::test_preset() },
    };
    if backend == BackendKind::Virtual {
        cfg.dims = Some(dims.clone());
    }
    let vocab = dims.vocab;
    eprintln!(
        "training with the {} schedule on the {} backend, {steps} steps x {} microbatches",
        schedule.name(),
        backend.name(),
        cfg.n_mb
    );

    let report = train(&cfg)?;

    println!("\nloss curve (step, mean loss):");
    for s in &report.steps {
        println!("{:4}  {:.4}", s.step, s.mean_loss);
    }
    let corpus = Corpus::new(vocab, cfg.seed);
    println!(
        "\nfirst {:.4} -> last {:.4} (uniform ln V = {:.3}, corpus entropy floor ≈ {:.3})",
        report.first_loss(),
        report.last_loss(),
        (vocab as f64).ln(),
        corpus.entropy_floor(),
    );
    println!(
        "wall {:.1}s | {} unit execs | {:.1} MB all-reduced | peak act/stage {:?} MB",
        report.wall_secs,
        report.executions,
        report.allreduce_bytes as f64 / 1e6,
        report.peak_activation_bytes.iter().map(|b| b / 1_000_000).collect::<Vec<_>>(),
    );
    assert!(report.last_loss().is_finite(), "training diverged — non-finite loss");
    assert!(
        report.last_loss() < report.first_loss(),
        "loss did not decrease — training is broken"
    );
    println!("OK: loss decreased under the {} schedule", schedule.name());
    Ok(())
}
