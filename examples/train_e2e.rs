//! End-to-end validation (DESIGN.md §5): train a ~100M-parameter
//! Qwen2-style transformer with REAL compute through all three layers —
//! Pallas kernels (L1) lowered through the JAX model (L2) into HLO
//! artifacts that this rust coordinator (L3) executes under the paper's
//! STP schedule with genuine TP All-Reduce and pipeline P2P between
//! threads — and log the loss curve.
//!
//! ```text
//! make artifacts                       # once (python, build path only)
//! cargo run --release --example train_e2e -- [steps] [schedule]
//! ```
//!
//! TP=2 × PP=2 × 2 virtual chunks (the manifest's topology). Loss starts
//! near ln(V) ≈ 9.01 and must fall toward the synthetic bigram corpus's
//! entropy floor. The run is recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use stp::exec::{train, Corpus, TrainConfig};
use stp::schedule::ScheduleKind;

fn main() -> stp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let schedule: ScheduleKind = args
        .get(1)
        .map(|s| s.parse().expect("bad schedule name"))
        .unwrap_or(ScheduleKind::Stp);

    let cfg = TrainConfig {
        artifacts_dir: PathBuf::from("artifacts/e2e"),
        schedule,
        n_mb: 4,
        steps,
        lr: 0.03,
        seed: 42,
        verbose: true,
    };
    eprintln!(
        "training tiny-100m with the {} schedule, {steps} steps x {} microbatches",
        schedule.name(),
        cfg.n_mb
    );

    let report = train(&cfg)?;

    println!("\nloss curve (step, mean loss):");
    for s in &report.steps {
        println!("{:4}  {:.4}", s.step, s.mean_loss);
    }
    let corpus = Corpus::new(8192, cfg.seed);
    println!(
        "\nfirst {:.4} -> last {:.4} (uniform ln V = {:.3}, corpus entropy floor ≈ {:.3})",
        report.first_loss(),
        report.last_loss(),
        (8192f64).ln(),
        corpus.entropy_floor(),
    );
    println!(
        "wall {:.1}s | {} PJRT execs | {:.1} MB all-reduced | peak act/stage {:?} MB",
        report.wall_secs,
        report.executions,
        report.allreduce_bytes as f64 / 1e6,
        report.peak_activation_bytes.iter().map(|b| b / 1_000_000).collect::<Vec<_>>(),
    );
    assert!(
        report.last_loss() < report.first_loss(),
        "loss did not decrease — training is broken"
    );
    println!("OK: loss decreased under the {} schedule", schedule.name());
    Ok(())
}
