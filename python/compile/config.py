"""Model/topology configuration shared by the L1 kernels, the L2 model and
the AOT lowering pipeline.

The ``e2e`` preset is the ~100M-parameter Qwen2-style decoder trained by
``examples/train_e2e.rs``; ``test`` is a miniature of the same architecture
used by the pytest suites so kernel sweeps stay fast.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Dims:
    """Architecture + partitioning dimensions.

    Attributes mirror the rust `ModelConfig` (rust/src/model/mod.rs); the
    AOT manifest carries these so the two sides cannot drift.
    """

    vocab: int
    d: int          # hidden size
    q_heads: int
    kv_heads: int
    ffn: int        # SwiGLU intermediate
    layers: int
    seq: int        # tokens per microbatch row
    mb: int         # microbatch size (rows)
    tp: int         # tensor-parallel size
    pp: int = 2     # pipeline stages (metadata for the manifest)
    vpp: int = 2    # virtual stages per device

    @property
    def head_dim(self) -> int:
        assert self.d % self.q_heads == 0
        return self.d // self.q_heads

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def q_heads_per_rank(self) -> int:
        assert self.q_heads % self.tp == 0
        return self.q_heads // self.tp

    @property
    def kv_heads_per_rank(self) -> int:
        assert self.kv_heads % self.tp == 0
        return self.kv_heads // self.tp

    @property
    def ffn_per_rank(self) -> int:
        assert self.ffn % self.tp == 0
        return self.ffn // self.tp

    @property
    def n_chunks(self) -> int:
        return self.pp * self.vpp

    @property
    def layers_per_chunk(self) -> int:
        assert self.layers % self.n_chunks == 0
        return self.layers // self.n_chunks

    def params_count(self) -> int:
        """Total parameter count (embed + layers + head)."""
        attn = self.d * self.d + 2 * self.d * self.kv_dim + self.d * self.d
        mlp = 3 * self.d * self.ffn
        norms = 2 * self.d
        per_layer = attn + mlp + norms
        return self.layers * per_layer + 2 * self.vocab * self.d


# ~100M-parameter end-to-end training config (examples/train_e2e.rs).
E2E = Dims(
    vocab=8192,
    d=512,
    q_heads=8,
    kv_heads=4,
    ffn=2048,
    layers=20,
    seq=64,
    mb=1,
    tp=2,
    pp=2,
    vpp=2,
)

# Miniature config for pytest (same architecture family).
TEST = Dims(
    vocab=256,
    d=64,
    q_heads=4,
    kv_heads=2,
    ffn=96,
    layers=4,
    seq=16,
    mb=2,
    tp=2,
    pp=2,
    vpp=2,
)

PRESETS = {"e2e": E2E, "test": TEST}
