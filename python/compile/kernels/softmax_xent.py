"""L1 Pallas kernel: LM-head cross-entropy loss (the last chunk's unit).

Grids over token-row blocks; each step computes the block's logits panel
(`x @ W_head`), a numerically-stable log-softmax, and gathers the target
log-probs via a one-hot dot (gather is awkward on the VPU; one-hot matmul
rides the MXU instead). The mean reduction happens outside the kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xent_kernel(x_ref, wh_ref, t_ref, nll_ref):
    x = x_ref[...]                       # [br, D]
    logits = jnp.dot(x, wh_ref[...], preferred_element_type=jnp.float32)  # [br, V]
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1, keepdims=True)) + m
    logp = logits - lse
    v = logits.shape[-1]
    tgt = t_ref[...]                     # [br] int32
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (tgt.shape[0], v), 1) == tgt[:, None]
    ).astype(jnp.float32)
    nll_ref[...] = -jnp.sum(logp * onehot, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def xent_nll(x, w_head, targets, block_rows: int = 64):
    """Per-token negative log-likelihood. x: [N, D], targets: [N] int32."""
    n, d = x.shape
    v = w_head.shape[1]
    br = min(block_rows, n)
    while n % br != 0:
        br -= 1
    return pl.pallas_call(
        _xent_kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, w_head, targets)


def head_loss(x, w_head, targets):
    """Mean cross-entropy for x [mb,S,D] against targets [mb,S]."""
    mb, s, d = x.shape
    nll = xent_nll(x.reshape(mb * s, d), w_head, targets.reshape(mb * s))
    return jnp.mean(nll)
