"""L1 Pallas kernel: the MLP computation unit (paper §3).

Per-TP-rank SwiGLU with the residual fused before the All-Reduce:

    partial_r = (silu(x_ln @ Wg_r) * (x_ln @ Wu_r)) @ Wd_r + x / t

The fused kernel grids over token-row blocks; each grid step holds the
rank's three weight panels in VMEM (column-parallel gate/up, row-parallel
down) and performs three MXU matmuls plus the SwiGLU elementwise in one
pass — the TPU rendition of the paper's fused MLP unit boundary.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import Dims
from .layernorm import rmsnorm


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u
    o_ref[...] = jnp.dot(h.astype(x.dtype), wd_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_rows",))
def swiglu(x_ln, wg_r, wu_r, wd_r, block_rows: int = 128):
    """Fused SwiGLU over row blocks. x_ln: [mb,S,D]; returns [mb,S,D]."""
    mb, s, d = x_ln.shape
    f = wg_r.shape[1]
    rows = mb * s
    br = min(block_rows, rows)
    while rows % br != 0:
        br -= 1
    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x_ln.dtype),
        interpret=True,
    )(x_ln.reshape(rows, d), wg_r, wu_r, wd_r)
    return out.reshape(mb, s, d)


def mlp_unit(x, gamma2, wg_r, wu_r, wd_r, dims: Dims):
    """The full per-rank MLP unit: RMSNorm -> SwiGLU -> +x/t.

    Lowered by `aot.py` to `mlp_fwd.hlo.txt`; outputs are All-Reduced by
    the rust coordinator.
    """
    x_ln = rmsnorm(x, gamma2)
    h = swiglu(x_ln, wg_r, wu_r, wd_r)
    return h + jax.lax.stop_gradient(x) / dims.tp


def vmem_bytes(block_rows: int, d: int, f: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint of one grid step (x, 3 weights, h, out)."""
    return (block_rows * d * 2 + 2 * d * f + f * d + block_rows * f) * dtype_bytes
