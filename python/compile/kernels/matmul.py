"""L1 Pallas building block: tiled matmul targeting the MXU.

Hardware adaptation (DESIGN.md §2): the paper's CUDA GEMMs become a
Pallas grid over (M, N, K) tiles. Block shapes are multiples of (8, 128)
for f32 so Mosaic would map the inner ``jnp.dot`` onto the 128x128
systolic array; the K loop accumulates in the output block (VMEM
scratchpad), which is the TPU analogue of the threadblock accumulator.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated structurally (VMEM
footprint + MXU utilization) in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile: accumulate over the K grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` not exceeding `target` (keeps the grid
    exact without padding logic; fine for the power-of-two shapes used
    throughout)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm: int = 128, bn: int = 128, bk: int = 512):
    """Tiled ``a @ b`` via Pallas. a: [M, K], b: [K, N] -> [M, N]."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)


def matmul_3d(x, w, **kw):
    """[mb, S, K] @ [K, N] -> [mb, S, N] (rows flattened into the grid)."""
    mb, s, k = x.shape
    out = matmul(x.reshape(mb * s, k), w, **kw)
    return out.reshape(mb, s, w.shape[1])


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint of one grid step (A, B, O tiles)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes
