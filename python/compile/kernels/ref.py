"""Pure-jnp reference oracles for every L1 kernel.

These are the correctness ground truth: pytest asserts each Pallas kernel
allclose against its oracle, and the TP-decomposition invariants
(sum-over-ranks == dense layer, paper Eq. 1-2) are stated here once and
checked for every shape the hypothesis sweeps generate.
"""

import jax
import jax.numpy as jnp

from ..config import Dims


# ---------------------------------------------------------------------------
# Elementwise / norm
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-6):
    """RMSNorm (Qwen2 uses RMSNorm, not LayerNorm)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gamma


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Attention unit (paper Eq. 1): per-TP-rank partial with fused residual.
# ---------------------------------------------------------------------------

def attention_core(x_ln, wq, wk, wv, wo, q_heads, kv_heads, causal=True):
    """Multi-head attention over whatever head slice the weights carry.

    x_ln: [mb, S, D]; wq: [D, hq*dh]; wk/wv: [D, hkv*dh]; wo: [hq*dh, D].
    GQA: each kv head serves q_heads//kv_heads query heads.
    """
    mb, s, _d = x_ln.shape
    dh = wq.shape[1] // q_heads
    q = x_ln @ wq  # [mb, S, hq*dh]
    k = x_ln @ wk
    v = x_ln @ wv
    q = q.reshape(mb, s, q_heads, dh).transpose(0, 2, 1, 3)  # [mb,hq,S,dh]
    k = k.reshape(mb, s, kv_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(mb, s, kv_heads, dh).transpose(0, 2, 1, 3)
    group = q_heads // kv_heads
    k = jnp.repeat(k, group, axis=1)  # [mb,hq,S,dh]
    v = jnp.repeat(v, group, axis=1)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(dh))
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = probs @ v  # [mb,hq,S,dh]
    ctx = ctx.transpose(0, 2, 1, 3).reshape(mb, s, q_heads * dh)
    return ctx @ wo


def attn_unit_partial(x, gamma1, wq_r, wk_r, wv_r, wo_r, dims: Dims):
    """Per-rank Attn unit forward (paper Eq. 1, pre-All-Reduce):

        partial_r = Attention_r(RMSNorm(x)) + detach(x)/t

    Summing ``partial_r`` over the ``t`` ranks (the All-Reduce the rust
    coordinator performs) yields ``Attention(LN(x)) + x`` — the residual
    attention block with the residual fused before the AR, so the unit
    ends exactly at an AR boundary (what the braided blocks need).
    """
    x_ln = rmsnorm(x, gamma1)
    attn = attention_core(
        x_ln, wq_r, wk_r, wv_r, wo_r,
        dims.q_heads_per_rank, dims.kv_heads_per_rank,
    )
    return attn + jax.lax.stop_gradient(x) / dims.tp


def mlp_unit_partial(x, gamma2, wg_r, wu_r, wd_r, dims: Dims):
    """Per-rank MLP (SwiGLU) unit forward with fused residual:

        partial_r = (silu(x_ln @ Wg_r) * (x_ln @ Wu_r)) @ Wd_r + detach(x)/t
    """
    x_ln = rmsnorm(x, gamma2)
    h = silu(x_ln @ wg_r) * (x_ln @ wu_r)
    return h @ wd_r + jax.lax.stop_gradient(x) / dims.tp


# ---------------------------------------------------------------------------
# Dense (non-TP) layer for the sum-over-ranks invariant.
# ---------------------------------------------------------------------------

def dense_layer(x, params, dims: Dims):
    """Unpartitioned transformer layer: what the TP ranks must sum to."""
    x_ln = rmsnorm(x, params["gamma1"])
    attn = attention_core(
        x_ln, params["wq"], params["wk"], params["wv"], params["wo"],
        dims.q_heads, dims.kv_heads,
    )
    y = attn + x
    y_ln = rmsnorm(y, params["gamma2"])
    h = silu(y_ln @ params["wg"]) * (y_ln @ params["wu"])
    return h @ params["wd"] + y


def shard_layer(params, dims: Dims):
    """Megatron-slice full-layer params into per-rank params.

    Q/K/V and gate/up are column-parallel (output split), O and down-proj
    are row-parallel (input split); norms are replicated.
    """
    t = dims.tp
    dh = dims.head_dim
    out = []
    for r in range(t):
        qs = slice(r * dims.q_heads_per_rank * dh, (r + 1) * dims.q_heads_per_rank * dh)
        ks = slice(r * dims.kv_heads_per_rank * dh, (r + 1) * dims.kv_heads_per_rank * dh)
        fs = slice(r * dims.ffn_per_rank, (r + 1) * dims.ffn_per_rank)
        out.append({
            "gamma1": params["gamma1"],
            "wq": params["wq"][:, qs],
            "wk": params["wk"][:, ks],
            "wv": params["wv"][:, ks],
            "wo": params["wo"][qs, :],
            "gamma2": params["gamma2"],
            "wg": params["wg"][:, fs],
            "wu": params["wu"][:, fs],
            "wd": params["wd"][fs, :],
        })
    return out


def init_layer(key, dims: Dims, dtype=jnp.float32):
    """Random full-layer params (1/sqrt(fan_in) scaled)."""
    ks = jax.random.split(key, 7)
    d, kv, f = dims.d, dims.kv_dim, dims.ffn

    def scaled(k, shape):
        return jax.random.normal(k, shape, dtype) / jnp.sqrt(jnp.float32(shape[0]))

    return {
        "gamma1": jnp.ones((d,), dtype),
        "wq": scaled(ks[0], (d, d)),
        "wk": scaled(ks[1], (d, kv)),
        "wv": scaled(ks[2], (d, kv)),
        "wo": scaled(ks[3], (d, d)),
        "gamma2": jnp.ones((d,), dtype),
        "wg": scaled(ks[4], (d, f)),
        "wu": scaled(ks[5], (d, f)),
        "wd": scaled(ks[6], (f, d)),
    }


# ---------------------------------------------------------------------------
# Endpoints
# ---------------------------------------------------------------------------

def xent_loss(logits, targets):
    """Mean token cross-entropy. logits [N, V], targets int32 [N]."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def head_loss(x, w_head, targets):
    """LM head + loss: x [mb,S,D] @ w_head [D,V] vs targets [mb,S]."""
    mb, s, d = x.shape
    logits = x.reshape(mb * s, d) @ w_head
    return xent_loss(logits, targets.reshape(mb * s))


def embed(tokens, emb):
    """Token embedding lookup: tokens [mb,S] int32, emb [V,D]."""
    return emb[tokens]


def tiled_matmul(a, b):
    """Oracle for the Pallas tiled matmul building block."""
    return a @ b
