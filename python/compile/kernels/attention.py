"""L1 Pallas kernel: the Attn computation unit (paper §3, Eq. 1).

The unit is the per-TP-rank slice of causal multi-head attention with the
residual fused in *before* the All-Reduce boundary:

    partial_r = Attention_r(x_ln) + x / t

Hardware adaptation: instead of the paper's CUDA warp-level kernels, the
softmax(QKᵀ)V core is a Pallas program gridded over (batch, head); each
grid step holds one head's Q/K/V panels for the whole (short) sequence in
VMEM and runs two MXU matmuls with a numerically-stable softmax between.
The surrounding projections use the tiled MXU matmul building block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import Dims
from .layernorm import rmsnorm
from .matmul import matmul_3d


def _attn_core_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One (batch, head): softmax(QKᵀ·scale + causal)V.

    q/k/v refs: [1, 1, S, dh] panels in VMEM; o ref: [1, 1, S, dh].
    """
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    s = q.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask via 2D iota (TPU-friendly: no 1D iota).
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(rows >= cols, scores, jnp.finfo(jnp.float32).min)
    # Numerically-stable softmax.
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(probs.astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("q_heads", "kv_heads"))
def attention_core(x_ln, wq, wk, wv, wo, q_heads: int, kv_heads: int):
    """Causal GQA attention over the weights' head slice (see ref.py)."""
    mb, s, d = x_ln.shape
    dh = wq.shape[1] // q_heads
    scale = 1.0 / (dh ** 0.5)
    group = q_heads // kv_heads

    q = matmul_3d(x_ln, wq).reshape(mb, s, q_heads, dh).transpose(0, 2, 1, 3)
    k = matmul_3d(x_ln, wk).reshape(mb, s, kv_heads, dh).transpose(0, 2, 1, 3)
    v = matmul_3d(x_ln, wv).reshape(mb, s, kv_heads, dh).transpose(0, 2, 1, 3)
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)

    ctx = pl.pallas_call(
        functools.partial(_attn_core_kernel, scale=scale),
        grid=(mb, q_heads),
        in_specs=[
            pl.BlockSpec((1, 1, s, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, s, dh), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, s, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((mb, q_heads, s, dh), x_ln.dtype),
        interpret=True,
    )(q, k, v)

    ctx = ctx.transpose(0, 2, 1, 3).reshape(mb, s, q_heads * dh)
    return matmul_3d(ctx, wo)


def attn_unit(x, gamma1, wq_r, wk_r, wv_r, wo_r, dims: Dims):
    """The full per-rank Attn unit: RMSNorm -> attention -> +x/t.

    This is what `aot.py` lowers to `attn_fwd.hlo.txt`; the rust
    coordinator All-Reduces the outputs across the TP group.
    """
    x_ln = rmsnorm(x, gamma1)
    attn = attention_core(
        x_ln, wq_r, wk_r, wv_r, wo_r,
        dims.q_heads_per_rank, dims.kv_heads_per_rank,
    )
    return attn + jax.lax.stop_gradient(x) / dims.tp
