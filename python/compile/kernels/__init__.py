"""L1 — Pallas kernels for the paper's fine-grained computation units.

Every kernel has a pure-jnp oracle in :mod:`.ref`; pytest sweeps shapes
with hypothesis and asserts allclose. All kernels run ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls); see DESIGN.md section 2 for
the GPU-to-TPU hardware adaptation and section Perf for the structural
VMEM/MXU estimates.
"""

from . import ref
from .attention import attention_core, attn_unit
from .layernorm import rmsnorm
from .matmul import matmul, matmul_3d
from .mlp import mlp_unit, swiglu
from .softmax_xent import head_loss, xent_nll

__all__ = [
    "ref", "attention_core", "attn_unit", "rmsnorm", "matmul", "matmul_3d",
    "mlp_unit", "swiglu", "head_loss", "xent_nll",
]
