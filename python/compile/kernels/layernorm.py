"""L1 Pallas kernel: RMSNorm — the Pre-Attn / Pre-MLP computation units.

The paper's fine-grained decomposition (§3) splits these out of the Attn
and MLP units because they carry no TP communication: they are inserted
into the compute stream purely by data dependency. Bandwidth-bound, so
the BlockSpec tiles rows (tokens) and keeps the full hidden dim resident.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def rmsnorm(x, gamma, block_rows: int = 128, eps: float = 1e-6):
    """RMSNorm over the last axis. x: [mb, S, D], gamma: [D]."""
    mb, s, d = x.shape
    rows = mb * s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br != 0:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x2, gamma)
    return out.reshape(mb, s, d)
