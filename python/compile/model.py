"""L2 — the JAX model: per-TP-rank computation units with Zero-Bubble-style
decomposed backwards.

The paper's schedule operates on four unit kinds per layer (Pre-Attn,
Attn, Pre-MLP, MLP) with backwards split into activation-gradient (`B`)
and weight-gradient (`W`) parts. This module defines exactly those
functions with **explicit parameters** (no closures over weights) so each
lowers to a standalone HLO artifact the rust executor can call per
(chunk, microbatch, unit):

* forward units call the L1 Pallas kernels;
* backward units are `jax.vjp` of the pure-jnp oracles (identical math;
  Pallas interpret-mode primitives are not differentiable), recomputing
  the unit forward internally — unit-level rematerialization keeps the
  cross-HLO interface to plain `(saved input, upstream grad)` tensors.

TP calculus (paper Eq. 1-2): every `*_fwd` / `*_bwd_x` output is a
per-rank **partial** that the rust coordinator All-Reduces; `*_bwd_w`
outputs are rank-local except the replicated RMSNorm gammas, which the
coordinator also All-Reduces (see `manifest["ar_outputs"]`).
"""

import jax
import jax.numpy as jnp

from .config import Dims
from .kernels import attn_unit, head_loss, mlp_unit, ref


# ---------------------------------------------------------------------------
# Forward units (Pallas, per rank)
# ---------------------------------------------------------------------------

def attn_fwd(x, gamma1, wq, wk, wv, wo, *, dims: Dims):
    """Attn unit forward partial (lowers `attn_fwd.hlo.txt`)."""
    return attn_unit(x, gamma1, wq, wk, wv, wo, dims)


def mlp_fwd(x, gamma2, wg, wu, wd, *, dims: Dims):
    """MLP unit forward partial (lowers `mlp_fwd.hlo.txt`)."""
    return mlp_unit(x, gamma2, wg, wu, wd, dims)


# ---------------------------------------------------------------------------
# Backward units (vjp of the oracles, per rank)
# ---------------------------------------------------------------------------

def attn_bwd_x(x, dy, gamma1, wq, wk, wv, wo, *, dims: Dims):
    """Attn unit activation-gradient partial (`B`, paper Eq. 2).

    `dy` is the *reduced* gradient of the unit's post-AR output. The
    returned partial satisfies `AR_r(out) = d(Attention(LN(x)) + x)/dx`:
    the vjp covers the attention path (the fused residual was detached in
    forward), and the `+ dy/t` term reconstitutes the residual's `+1`
    across the All-Reduce.
    """
    def f(xx):
        return ref.attn_unit_partial(xx, gamma1, wq, wk, wv, wo, dims)

    _, vjp = jax.vjp(f, x)
    (dx,) = vjp(dy)
    return dx + dy / dims.tp


def attn_bwd_w(x, dy, gamma1, wq, wk, wv, wo, *, dims: Dims):
    """Attn unit weight-gradient (`W`): rank-local dW, replicated dγ."""
    def f(g1, q, k, v, o):
        return ref.attn_unit_partial(x, g1, q, k, v, o, dims)

    _, vjp = jax.vjp(f, gamma1, wq, wk, wv, wo)
    return vjp(dy)  # (dgamma1, dwq, dwk, dwv, dwo)


def mlp_bwd_x(x, dy, gamma2, wg, wu, wd, *, dims: Dims):
    """MLP unit activation-gradient partial (`B`)."""
    def f(xx):
        return ref.mlp_unit_partial(xx, gamma2, wg, wu, wd, dims)

    _, vjp = jax.vjp(f, x)
    (dx,) = vjp(dy)
    return dx + dy / dims.tp


def mlp_bwd_w(x, dy, gamma2, wg, wu, wd, *, dims: Dims):
    """MLP unit weight-gradient (`W`)."""
    def f(g2, g, u, d):
        return ref.mlp_unit_partial(x, g2, g, u, d, dims)

    _, vjp = jax.vjp(f, gamma2, wg, wu, wd)
    return vjp(dy)  # (dgamma2, dwg, dwu, dwd)


# ---------------------------------------------------------------------------
# Pipeline endpoints
# ---------------------------------------------------------------------------

def embed_fwd(tokens, emb):
    """Token embedding (first chunk). Replicated across the TP group."""
    return ref.embed(tokens, emb)


def embed_bwd(tokens, dy, *, vocab: int):
    """Embedding gradient: scatter-add of `dy` rows into token slots."""
    mb, s, d = dy.shape
    flat_t = tokens.reshape(mb * s)
    flat_g = dy.reshape(mb * s, d)
    return jnp.zeros((vocab, d), dy.dtype).at[flat_t].add(flat_g)


def head_loss_grad(x, w_head, targets):
    """LM head + loss, fused fwd+bwd (the head is small and terminal):
    returns (loss, dx, dw_head). Uses the Pallas xent kernel forward and
    the oracle's vjp backward.
    """
    loss = head_loss(x, w_head, targets)

    def f(xx, wh):
        return ref.head_loss(xx, wh, targets)

    _, vjp = jax.vjp(f, x, w_head)
    dx, dwh = vjp(jnp.float32(1.0))
    return loss, dx, dwh


# ---------------------------------------------------------------------------
# Reference whole-model step (oracle for the rust pipeline's numerics)
# ---------------------------------------------------------------------------

def dense_forward(tokens, emb, layers_params, w_head, dims: Dims):
    """Unpartitioned forward through all layers (test oracle)."""
    x = ref.embed(tokens, emb)
    for p in layers_params:
        x = ref.dense_layer(x, p, dims)
    return x


def dense_loss(tokens, targets, emb, layers_params, w_head, dims: Dims):
    x = dense_forward(tokens, emb, layers_params, w_head, dims)
    return ref.head_loss(x, w_head, targets)


def smoke(x, y):
    """Tiny known-answer computation for the rust runtime smoke test:
    matmul(x, y) + 2 over f32[2,2] (mirrors /opt/xla-example)."""
    return jnp.matmul(x, y) + 2.0
