"""Build-time Python (L1 Pallas kernels + L2 JAX model + AOT lowering).

Never imported at runtime: ``make artifacts`` runs once, emitting HLO
text under ``artifacts/`` that the rust coordinator loads via PJRT.
"""
