"""AOT lowering: JAX/Pallas units → HLO *text* artifacts + manifest.

Interchange is HLO text, NOT serialized ``HloModuleProto``: jax ≥ 0.5
emits protos with 64-bit instruction ids that the rust crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --preset e2e --out ../artifacts
    python -m compile.aot --preset test --out ../artifacts --golden

Emits ``<out>/<preset>/<name>.hlo.txt`` per unit, a ``manifest.json``
describing argument/output shapes and which outputs the rust coordinator
must All-Reduce, and (with ``--golden``) known-answer vectors for the
rust runtime integration tests. Python runs ONCE at build time; the rust
binary is self-contained afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .config import PRESETS, Dims


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def unit_signatures(dims: Dims):
    """Name → (callable, example arg specs, #outputs, AR'd output indices).

    Shapes are per-TP-rank (the rust executor owns one HLO executable per
    unit kind; layer weights are passed as arguments so one executable
    serves every layer).
    """
    d = dims.d
    mbs = (dims.mb, dims.seq, d)
    dh = dims.head_dim
    qr = dims.q_heads_per_rank * dh
    kr = dims.kv_heads_per_rank * dh
    fr = dims.ffn_per_rank
    f32 = jnp.float32
    i32 = jnp.int32

    x = spec(mbs)
    dy = spec(mbs)
    g = spec((d,))
    wq, wk, wv, wo = spec((d, qr)), spec((d, kr)), spec((d, kr)), spec((qr, d))
    wg, wu, wd = spec((d, fr)), spec((d, fr)), spec((fr, d))
    tok = spec((dims.mb, dims.seq), i32)
    emb = spec((dims.vocab, d))
    wh = spec((d, dims.vocab))

    def with_dims(fn):
        return functools.partial(fn, dims=dims)

    return {
        "attn_fwd": (with_dims(model.attn_fwd), [x, g, wq, wk, wv, wo], 1, [0]),
        "attn_bwd_x": (with_dims(model.attn_bwd_x), [x, dy, g, wq, wk, wv, wo], 1, [0]),
        "attn_bwd_w": (with_dims(model.attn_bwd_w), [x, dy, g, wq, wk, wv, wo], 5, [0]),
        "mlp_fwd": (with_dims(model.mlp_fwd), [x, g, wg, wu, wd], 1, [0]),
        "mlp_bwd_x": (with_dims(model.mlp_bwd_x), [x, dy, g, wg, wu, wd], 1, [0]),
        "mlp_bwd_w": (with_dims(model.mlp_bwd_w), [x, dy, g, wg, wu, wd], 4, [0]),
        "embed_fwd": (model.embed_fwd, [tok, emb], 1, []),
        "embed_bwd": (
            functools.partial(model.embed_bwd, vocab=dims.vocab),
            [tok, dy],
            1,
            [],
        ),
        "head_loss_grad": (model.head_loss_grad, [x, wh, tok], 3, []),
        "smoke": (model.smoke, [spec((2, 2), f32), spec((2, 2), f32)], 1, []),
    }


def describe(s: jax.ShapeDtypeStruct):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_all(preset: str, out_dir: str, golden: bool) -> None:
    dims = PRESETS[preset]
    sigs = unit_signatures(dims)
    pdir = os.path.join(out_dir, preset)
    os.makedirs(pdir, exist_ok=True)

    manifest = {
        "preset": preset,
        "dims": dims.__dict__,
        "params_count": dims.params_count(),
        "artifacts": {},
    }
    for name, (fn, args, n_out, ar_outs) in sigs.items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(pdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "args": [describe(a) for a in args],
            "n_outputs": n_out,
            "ar_outputs": ar_outs,
        }
        print(f"lowered {preset}/{name}: {len(text)} chars")

    if golden:
        write_golden(dims, pdir)

    with open(os.path.join(pdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {pdir}/manifest.json")


def write_golden(dims: Dims, pdir: str) -> None:
    """Known-answer vectors for the rust runtime integration test: run the
    per-rank units here, record inputs/outputs flat, and let rust execute
    the same HLO and compare."""
    from .kernels import ref

    key = jax.random.PRNGKey(0)
    kx, kp = jax.random.split(key)
    x = jax.random.normal(kx, (dims.mb, dims.seq, dims.d), jnp.float32) * 0.5
    params = ref.init_layer(kp, dims)
    shard = ref.shard_layer(params, dims)[0]

    attn_out = model.attn_fwd(
        x, shard["gamma1"], shard["wq"], shard["wk"], shard["wv"], shard["wo"], dims=dims
    )
    mlp_out = model.mlp_fwd(
        x, shard["gamma2"], shard["wg"], shard["wu"], shard["wd"], dims=dims
    )

    def flat(a):
        return np.asarray(a, dtype=np.float32).reshape(-1).tolist()

    golden = {
        "x": flat(x),
        "gamma1": flat(shard["gamma1"]),
        "wq": flat(shard["wq"]),
        "wk": flat(shard["wk"]),
        "wv": flat(shard["wv"]),
        "wo": flat(shard["wo"]),
        "gamma2": flat(shard["gamma2"]),
        "wg": flat(shard["wg"]),
        "wu": flat(shard["wu"]),
        "wd": flat(shard["wd"]),
        "attn_fwd_out": flat(attn_out),
        "mlp_fwd_out": flat(mlp_out),
    }
    with open(os.path.join(pdir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"wrote {pdir}/golden.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="e2e", choices=sorted(PRESETS))
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--golden", action="store_true")
    args = ap.parse_args()
    lower_all(args.preset, args.out, args.golden)


if __name__ == "__main__":
    main()
