"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
plus hypothesis sweeps over shapes/dtypes (the system's core correctness
signal — see DESIGN.md §4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import TEST, Dims
from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

RTOL = 2e-5
ATOL = 2e-5


def allclose(a, b, rtol=RTOL, atol=ATOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# Tiled matmul
# ---------------------------------------------------------------------------

class TestMatmul:
    @settings(max_examples=12, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 32, 64]),
        k=st.sampled_from([16, 32, 128]),
        n=st.sampled_from([8, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle(self, m, k, n, seed):
        ka, kb = jax.random.split(jax.random.PRNGKey(seed))
        a, b = rand(ka, m, k), rand(kb, k, n)
        allclose(kernels.matmul(a, b), ref.tiled_matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_non_square_blocks(self):
        key = jax.random.PRNGKey(0)
        a, b = rand(key, 24, 48), rand(key, 48, 40)
        allclose(kernels.matmul(a, b, bm=8, bn=8, bk=16), a @ b, rtol=1e-4, atol=1e-4)

    def test_3d_variant(self):
        key = jax.random.PRNGKey(1)
        x, w = rand(key, 2, 16, 32), rand(key, 32, 24)
        allclose(kernels.matmul_3d(x, w), x @ w, rtol=1e-4, atol=1e-4)

    def test_block_larger_than_dim(self):
        key = jax.random.PRNGKey(2)
        a, b = rand(key, 4, 4), rand(key, 4, 4)
        allclose(kernels.matmul(a, b), a @ b)


# ---------------------------------------------------------------------------
# RMSNorm (Pre-Attn / Pre-MLP units)
# ---------------------------------------------------------------------------

class TestRmsNorm:
    @settings(max_examples=10, deadline=None)
    @given(
        mb=st.sampled_from([1, 2, 3]),
        s=st.sampled_from([4, 16, 17]),
        d=st.sampled_from([8, 64, 96]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle(self, mb, s, d, seed):
        kx, kg = jax.random.split(jax.random.PRNGKey(seed))
        x = rand(kx, mb, s, d)
        g = rand(kg, d)
        allclose(kernels.rmsnorm(x, g), ref.rmsnorm(x, g))

    def test_unit_gamma_preserves_rms(self):
        x = rand(jax.random.PRNGKey(0), 2, 8, 64) * 3.0
        y = kernels.rmsnorm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
        allclose(rms, jnp.ones_like(rms), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Attention unit (Eq. 1)
# ---------------------------------------------------------------------------

def make_dims(d, q_heads, kv_heads, ffn, seq, mb, tp):
    return Dims(vocab=64, d=d, q_heads=q_heads, kv_heads=kv_heads, ffn=ffn,
                seq=seq, mb=mb, tp=tp, layers=2)


class TestAttentionUnit:
    @settings(max_examples=8, deadline=None)
    @given(
        seq=st.sampled_from([4, 8, 16]),
        heads=st.sampled_from([(4, 2), (4, 4), (8, 2)]),
        tp=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle_per_rank(self, seq, heads, tp, seed):
        q_heads, kv_heads = heads
        dims = make_dims(32, q_heads, kv_heads, 48, seq, 2, tp)
        key = jax.random.PRNGKey(seed)
        kx, kp = jax.random.split(key)
        x = rand(kx, dims.mb, seq, dims.d)
        params = ref.init_layer(kp, dims)
        for r, p in enumerate(ref.shard_layer(params, dims)):
            got = kernels.attn_unit(x, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
            want = ref.attn_unit_partial(x, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
            allclose(got, want)

    def test_causality(self):
        # Changing a future token must not change past outputs.
        dims = TEST
        key = jax.random.PRNGKey(0)
        kx, kp = jax.random.split(key)
        x = rand(kx, 1, dims.seq, dims.d)
        p = ref.shard_layer(ref.init_layer(kp, dims), dims)[0]
        y1 = kernels.attn_unit(x, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
        x2 = x.at[0, -1].add(10.0)
        y2 = kernels.attn_unit(x2, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
        allclose(y1[0, :-1], y2[0, :-1])
        assert not np.allclose(np.asarray(y1[0, -1]), np.asarray(y2[0, -1]))

    def test_gqa_equals_repeated_mha(self):
        # kv_heads=q_heads GQA must equal plain MHA math.
        dims = make_dims(32, 4, 4, 48, 8, 1, 1)
        key = jax.random.PRNGKey(3)
        kx, kp = jax.random.split(key)
        x = rand(kx, 1, 8, 32)
        p = ref.init_layer(kp, dims)
        got = kernels.attention_core(x, p["wq"], p["wk"], p["wv"], p["wo"], 4, 4)
        want = ref.attention_core(x, p["wq"], p["wk"], p["wv"], p["wo"], 4, 4)
        allclose(got, want)


# ---------------------------------------------------------------------------
# MLP unit
# ---------------------------------------------------------------------------

class TestMlpUnit:
    @settings(max_examples=8, deadline=None)
    @given(
        d=st.sampled_from([16, 64]),
        ffn=st.sampled_from([32, 96]),
        tp=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle_per_rank(self, d, ffn, tp, seed):
        dims = make_dims(d, 4, 2, ffn, 8, 2, tp)
        key = jax.random.PRNGKey(seed)
        kx, kp = jax.random.split(key)
        x = rand(kx, 2, 8, d)
        params = ref.init_layer(kp, dims)
        for p in ref.shard_layer(params, dims):
            got = kernels.mlp_unit(x, p["gamma2"], p["wg"], p["wu"], p["wd"], dims)
            want = ref.mlp_unit_partial(x, p["gamma2"], p["wg"], p["wu"], p["wd"], dims)
            allclose(got, want)


# ---------------------------------------------------------------------------
# TP decomposition invariant (the heart of Eq. 1)
# ---------------------------------------------------------------------------

class TestTpInvariant:
    @settings(max_examples=6, deadline=None)
    @given(tp=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**31 - 1))
    def test_rank_sum_equals_dense_layer(self, tp, seed):
        dims = make_dims(32, 4, 4, 64, 8, 1, tp)
        key = jax.random.PRNGKey(seed)
        kx, kp = jax.random.split(key)
        x = rand(kx, 1, 8, 32)
        params = ref.init_layer(kp, dims)
        shards = ref.shard_layer(params, dims)
        # "All-Reduce" = sum over ranks.
        y = sum(
            ref.attn_unit_partial(x, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
            for p in shards
        )
        z = sum(
            ref.mlp_unit_partial(y, p["gamma2"], p["wg"], p["wu"], p["wd"], dims)
            for p in shards
        )
        allclose(z, ref.dense_layer(x, params, dims), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Cross-entropy head
# ---------------------------------------------------------------------------

class TestXent:
    @settings(max_examples=8, deadline=None)
    @given(
        n=st.sampled_from([4, 16, 32]),
        v=st.sampled_from([16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_oracle(self, n, v, seed):
        key = jax.random.PRNGKey(seed)
        kx, kw, kt = jax.random.split(key, 3)
        x = rand(kx, n, 8)
        wh = rand(kw, 8, v)
        t = jax.random.randint(kt, (n,), 0, v)
        got = kernels.xent_nll(x, wh, t)
        want = -jnp.take_along_axis(
            jax.nn.log_softmax(x @ wh, axis=-1), t[:, None], axis=-1
        )[:, 0]
        allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_uniform_logits_loss_is_log_v(self):
        v = 32
        x = jnp.zeros((8, 4))
        wh = jnp.zeros((4, v))
        t = jnp.arange(8) % v
        nll = kernels.xent_nll(x, wh, t)
        allclose(nll, jnp.full(8, np.log(v)), rtol=1e-5, atol=1e-5)
