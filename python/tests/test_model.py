"""L2 model correctness: the vjp-decomposed backward units must compose —
across B/W decoupling AND the TP All-Reduce — to exactly `jax.grad` of the
dense (unpartitioned) model. This is the invariant that lets the rust
pipeline schedule backward units independently (paper §3, Eq. 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.config import Dims
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def dims_for(tp, seq=8, d=32, layers=2):
    return Dims(vocab=64, d=d, q_heads=4, kv_heads=2, ffn=48,
                layers=layers, seq=seq, mb=2, tp=tp)


def rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


def allclose(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


def layer_fwd_tp(x, shards, dims):
    """One layer forward through the decomposed TP units (AR = sum)."""
    y = sum(
        ref.attn_unit_partial(x, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims)
        for p in shards
    )
    z = sum(
        ref.mlp_unit_partial(y, p["gamma2"], p["wg"], p["wu"], p["wd"], dims)
        for p in shards
    )
    return y, z


class TestBackwardDecomposition:
    @settings(max_examples=6, deadline=None)
    @given(tp=st.sampled_from([1, 2]), seed=st.integers(0, 2**31 - 1))
    def test_bwd_x_equals_dense_grad(self, tp, seed):
        """AR of per-rank B units == d(dense layer)/dx."""
        dims = dims_for(tp)
        key = jax.random.PRNGKey(seed)
        kx, kp, kd = jax.random.split(key, 3)
        x = rand(kx, dims.mb, dims.seq, dims.d)
        params = ref.init_layer(kp, dims)
        shards = ref.shard_layer(params, dims)
        dz = rand(kd, dims.mb, dims.seq, dims.d)

        y, _ = layer_fwd_tp(x, shards, dims)

        # Decomposed: MLP unit bwd at y, then Attn unit bwd at x.
        dy = sum(
            model.mlp_bwd_x(y, dz, p["gamma2"], p["wg"], p["wu"], p["wd"], dims=dims)
            for p in shards
        )
        dx = sum(
            model.attn_bwd_x(x, dy, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims=dims)
            for p in shards
        )

        # Oracle: full vjp through the dense layer.
        _, vjp = jax.vjp(lambda xx: ref.dense_layer(xx, params, dims), x)
        (dx_ref,) = vjp(dz)
        allclose(dx, dx_ref)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_bwd_w_equals_dense_grad(self, seed):
        """Per-rank W units == the rank's slice of d(dense)/dW; replicated
        gammas need the AR the manifest declares."""
        tp = 2
        dims = dims_for(tp)
        key = jax.random.PRNGKey(seed)
        kx, kp, kd = jax.random.split(key, 3)
        x = rand(kx, dims.mb, dims.seq, dims.d)
        params = ref.init_layer(kp, dims)
        shards = ref.shard_layer(params, dims)
        dz = rand(kd, dims.mb, dims.seq, dims.d)

        y, _ = layer_fwd_tp(x, shards, dims)
        dy = sum(
            model.mlp_bwd_x(y, dz, p["gamma2"], p["wg"], p["wu"], p["wd"], dims=dims)
            for p in shards
        )

        # Oracle full-parameter grads.
        def f(pp):
            return ref.dense_layer(x, pp, dims)

        _, vjp = jax.vjp(f, params)
        (dp_ref,) = vjp(dz)
        dp_ref_shards = ref.shard_layer(dp_ref, dims)

        for r, p in enumerate(shards):
            dg1, dwq, dwk, dwv, dwo = model.attn_bwd_w(
                x, dy, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims=dims
            )
            allclose(dwq, dp_ref_shards[r]["wq"])
            allclose(dwk, dp_ref_shards[r]["wk"])
            allclose(dwv, dp_ref_shards[r]["wv"])
            allclose(dwo, dp_ref_shards[r]["wo"])
            dg2, dwg, dwu, dwd = model.mlp_bwd_w(
                y, dz, p["gamma2"], p["wg"], p["wu"], p["wd"], dims=dims
            )
            allclose(dwg, dp_ref_shards[r]["wg"])
            allclose(dwu, dp_ref_shards[r]["wu"])
            allclose(dwd, dp_ref_shards[r]["wd"])

        # Gamma grads are per-rank partials: AR (sum) must equal the dense grad.
        dg1_sum = sum(
            model.attn_bwd_w(x, dy, p["gamma1"], p["wq"], p["wk"], p["wv"], p["wo"], dims=dims)[0]
            for p in shards
        )
        allclose(dg1_sum, dp_ref["gamma1"], rtol=5e-4, atol=5e-4)


class TestEndpoints:
    def test_embed_roundtrip(self):
        dims = dims_for(1)
        key = jax.random.PRNGKey(0)
        tok = jax.random.randint(key, (dims.mb, dims.seq), 0, dims.vocab)
        emb = rand(key, dims.vocab, dims.d)
        x = model.embed_fwd(tok, emb)
        assert x.shape == (dims.mb, dims.seq, dims.d)
        allclose(x[0, 0], emb[tok[0, 0]])

    def test_embed_bwd_is_grad(self):
        dims = dims_for(1)
        key = jax.random.PRNGKey(1)
        kt, ke, kd = jax.random.split(key, 3)
        tok = jax.random.randint(kt, (dims.mb, dims.seq), 0, dims.vocab)
        emb = rand(ke, dims.vocab, dims.d)
        dy = rand(kd, dims.mb, dims.seq, dims.d)
        got = model.embed_bwd(tok, dy, vocab=dims.vocab)
        _, vjp = jax.vjp(lambda e: model.embed_fwd(tok, e), emb)
        (want,) = vjp(dy)
        allclose(got, want)

    def test_head_loss_grad_matches_autodiff(self):
        dims = dims_for(1)
        key = jax.random.PRNGKey(2)
        kx, kw, kt = jax.random.split(key, 3)
        x = rand(kx, dims.mb, dims.seq, dims.d)
        wh = rand(kw, dims.d, dims.vocab)
        tok = jax.random.randint(kt, (dims.mb, dims.seq), 0, dims.vocab)
        loss, dx, dwh = model.head_loss_grad(x, wh, tok)
        want_loss, (want_dx, want_dwh) = jax.value_and_grad(
            lambda xx, ww: ref.head_loss(xx, ww, tok), argnums=(0, 1)
        )(x, wh)
        allclose(loss, want_loss, rtol=1e-5, atol=1e-6)
        allclose(dx, want_dx)
        allclose(dwh, want_dwh)

    def test_loss_decreases_under_sgd_dense(self):
        """A handful of dense SGD steps on random data must reduce loss —
        the python-side guarantee behind the rust e2e example."""
        dims = dims_for(1, seq=8, d=16, layers=2)
        key = jax.random.PRNGKey(3)
        kt, kp, ke, kh = jax.random.split(key, 4)
        tok = jax.random.randint(kt, (dims.mb, dims.seq), 0, dims.vocab)
        tgt = jnp.roll(tok, -1, axis=1)
        emb = rand(ke, dims.vocab, dims.d) * 0.1
        layers = [ref.init_layer(k, dims) for k in jax.random.split(kp, dims.layers)]
        wh = rand(kh, dims.d, dims.vocab) * 0.1

        def loss_fn(emb, layers, wh):
            return model.dense_loss(tok, tgt, emb, layers, wh, dims)

        val0 = loss_fn(emb, layers, wh)
        lr = 0.05
        for _ in range(8):
            val, grads = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(emb, layers, wh)
            demb, dlayers, dwh = grads
            emb = emb - lr * demb
            layers = jax.tree.map(lambda p, g: p - lr * g, layers, dlayers)
            wh = wh - lr * dwh
        val1 = loss_fn(emb, layers, wh)
        assert float(val1) < float(val0), f"loss {val0} -> {val1}"
